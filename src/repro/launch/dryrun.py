import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# The two lines above MUST run before any other import (jax locks the
# device count on first init).  This module is the multi-pod dry-run:
# it AOT-lowers + compiles every (architecture x input shape) cell on the
# production meshes — 16x16 (one pod) and 2x16x16 (two pods) — proving
# that every sharding in the system is coherent at 256/512 chips, and it
# extracts the roofline inputs (FLOPs / bytes / collective bytes) from
# the compiled artifact.  No array is ever allocated: inputs are
# ShapeDtypeStructs.
#
#   PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-4b \
#       --shape train_4k --mesh pod
#   PYTHONPATH=src python -m repro.launch.dryrun --all   (every cell)
import argparse
import gc
import json
import subprocess
import sys
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import compat
from repro.analysis import hlo as hlo_mod
from repro.analysis import roofline
from repro.configs.base import (SHAPES, MeshConfig, ModelConfig, ShapeSpec,
                                TrainConfig, default_microbatches, get_config)
from repro.configs import ALL_ARCHS
from repro.launch.mesh import describe, make_production_mesh
from repro.models import lm
from repro.parallel.sharding import make_rules, mesh_axis_size
from repro.serve import engine as serve_engine
from repro.train import step as train_step_mod

DEFAULT_OUT = "experiments/dryrun"


# ---------------------------------------------------------------------------
# input_specs — ShapeDtypeStruct stand-ins for every model input
# ---------------------------------------------------------------------------


def frontend_specs(cfg: ModelConfig, batch: int, seq: int,
                   kind: str) -> Optional[Dict[str, jax.ShapeDtypeStruct]]:
    """Stub modality frontends: precomputed frame/patch embeddings."""
    if cfg.frontend == "audio":
        s = 1 if kind == "decode" else seq
        return {"frame_embeds": jax.ShapeDtypeStruct(
            (batch, s, cfg.d_model), jnp.float32)}
    if cfg.frontend == "vlm" and kind != "decode":
        return {"prefix_embeds": jax.ShapeDtypeStruct(
            (batch, cfg.n_prefix_embeds, cfg.d_model), jnp.float32)}
    return None


def input_specs(arch: str, shape_name: str) -> Dict[str, Any]:
    """Every model input for one cell, as ShapeDtypeStructs."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    b, s = shape.global_batch, shape.seq_len
    toks = jax.ShapeDtypeStruct((b, s), jnp.int32)
    if shape.kind == "train":
        return {"tokens": toks, "labels": toks,
                "frontend": frontend_specs(cfg, b, s, "train")}
    if shape.kind == "prefill":
        return {"tokens": toks,
                "frontend": frontend_specs(cfg, b, s, "prefill")}
    # decode: one new token against a seq_len KV cache
    return {"token": jax.ShapeDtypeStruct((b, 1), jnp.int32),
            "caches": lm.cache_struct(cfg, b, s),
            "write_pos": jax.ShapeDtypeStruct((), jnp.int32),
            "frontend": frontend_specs(cfg, b, 1, "decode")}


# ---------------------------------------------------------------------------
# Per-cell training configuration (activation-residency knobs)
# ---------------------------------------------------------------------------


def pick_loss_chunk(cfg: ModelConfig, shape: ShapeSpec, mesh) -> int:
    """Bound the per-device logits chunk to ~256 MiB f32."""
    dp = 1
    for a in ("pod", "data"):
        dp *= mesh_axis_size(mesh, a)
    tp = mesh_axis_size(mesh, "model")
    b_dev = max(1, shape.global_batch // dp)
    v_dev = cfg.padded_vocab // tp if cfg.padded_vocab % tp == 0 \
        else cfg.padded_vocab
    budget = 256 << 20
    chunk = budget // max(1, b_dev * v_dev * 4)
    chunk = max(128, min(1024, (chunk // 128) * 128 or 128))
    return min(chunk, shape.seq_len)


def cell_train_config(cfg: ModelConfig, shape: ShapeSpec, mesh,
                      mesh_cfg: MeshConfig, *,
                      overrides: Optional[dict] = None) -> TrainConfig:
    tc = TrainConfig(
        microbatches=default_microbatches(cfg, shape, mesh_cfg),
        loss_chunk=pick_loss_chunk(cfg, shape, mesh),
        remat="layer", zero1=True)
    if overrides:
        import dataclasses
        tc = dataclasses.replace(tc, **overrides)
    return tc


# ---------------------------------------------------------------------------
# Cell lowering
# ---------------------------------------------------------------------------


def _shardings_for(mesh, struct, specs):
    return jax.tree.map(lambda s, sp: NamedSharding(mesh, sp), struct, specs)


def _batch_sharding(mesh, rules, struct):
    b = rules.batch if rules.batch else None
    if struct is None:
        return None
    def spec_of(s):
        return NamedSharding(mesh, P(b, *([None] * (len(s.shape) - 1))))
    return jax.tree.map(spec_of, struct)


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
               train_overrides: Optional[dict] = None,
               q_chunk: int = 256):
    """Build + lower + compile one cell. Returns (lowered, compiled, meta)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape_name == "long_500k" and not cfg.sub_quadratic:
        raise SystemExit(
            f"{arch} is pure full-attention: long_500k is skipped by "
            f"design (DESIGN.md §Arch-applicability)")
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_cfg = MeshConfig(pods=2 if multi_pod else 1)
    rules = make_rules(cfg, mesh, global_batch=shape.global_batch,
                       shape_kind=shape.kind)
    specs = input_specs(arch, shape_name)
    t0 = time.time()

    with mesh:
        if shape.kind == "train":
            tcfg = cell_train_config(cfg, shape, mesh, mesh_cfg,
                                     overrides=train_overrides)
            state_struct = train_step_mod.state_struct(cfg, tcfg)
            state_specs = train_step_mod.state_specs(
                cfg, rules, tcfg, state_struct["params"])
            state_sh = _shardings_for(mesh, state_struct, state_specs)
            tok_sh = _batch_sharding(mesh, rules, specs["tokens"])
            fe_sh = _batch_sharding(mesh, rules, specs["frontend"])
            step = train_step_mod.make_train_step(
                cfg, rules, tcfg, microbatches=tcfg.microbatches)
            jitted = jax.jit(step, in_shardings=(
                state_sh, tok_sh, tok_sh, fe_sh), donate_argnums=(0,))
            lowered = jitted.lower(state_struct, specs["tokens"],
                                   specs["labels"], specs["frontend"])
            meta_extra = {"microbatches": tcfg.microbatches,
                          "loss_chunk": tcfg.loss_chunk,
                          "remat": tcfg.remat, "zero1": tcfg.zero1}
        else:
            params_struct = jax.eval_shape(
                lambda: lm.init_params(cfg, jax.random.PRNGKey(0)))
            pspecs = lm.param_specs(rules, params_struct)
            params_sh = _shardings_for(mesh, params_struct, pspecs)
            fe_sh = _batch_sharding(mesh, rules, specs["frontend"])
            if shape.kind == "prefill":
                prefill = serve_engine.make_prefill_step(
                    cfg, rules, max_len=shape.seq_len, q_chunk=q_chunk)
                tok_sh = _batch_sharding(mesh, rules, specs["tokens"])
                jitted = jax.jit(prefill, in_shardings=(
                    params_sh, tok_sh, fe_sh))
                lowered = jitted.lower(params_struct, specs["tokens"],
                                       specs["frontend"])
            else:  # decode
                decode = serve_engine.make_decode_step(cfg, rules)
                cache_specs = lm.cache_specs(rules, specs["caches"])
                cache_sh = _shardings_for(mesh, specs["caches"],
                                          cache_specs)
                tok_sh = _batch_sharding(mesh, rules, specs["token"])
                pos_sh = NamedSharding(mesh, P())
                jitted = jax.jit(decode, in_shardings=(
                    params_sh, cache_sh, tok_sh, pos_sh, fe_sh),
                    donate_argnums=(1,))
                lowered = jitted.lower(params_struct, specs["caches"],
                                       specs["token"], specs["write_pos"],
                                       specs["frontend"])
            meta_extra = {"q_chunk": q_chunk}

        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    meta = {"arch": arch, "shape": shape_name,
            "mesh": describe(mesh), "multi_pod": multi_pod,
            "n_devices": mesh.size,
            "t_lower_s": round(t_lower, 1),
            "t_compile_s": round(t_compile, 1), **meta_extra}
    return lowered, compiled, meta


def analyze_cell(compiled, meta, cfg: ModelConfig,
                 shape: ShapeSpec) -> Dict[str, Any]:
    ma = compiled.memory_analysis()
    mem = {}
    if ma is not None:
        for k in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
            mem[k] = int(getattr(ma, k, 0))
    bytes_per_device = (mem.get("argument_size_in_bytes", 0)
                        + mem.get("temp_size_in_bytes", 0)
                        + mem.get("output_size_in_bytes", 0)
                        - mem.get("alias_size_in_bytes", 0))
    xla_cost = compat.cost_analysis(compiled)
    cost = hlo_mod.analyze(compiled.as_text())
    terms = roofline.compute_terms(
        cost, cfg=cfg, shape=shape, mesh_desc=meta["mesh"],
        n_devices=meta["n_devices"], bytes_per_device=bytes_per_device)
    rec = dict(meta)
    rec.update(
        memory_analysis=mem,
        bytes_per_device=bytes_per_device,
        xla_cost={k: float(v) for k, v in xla_cost.items()
                  if isinstance(v, (int, float))},
        hlo_flops=cost.flops,
        hlo_bytes=cost.bytes,
        movement_bytes=cost.movement_bytes,
        collective_bytes=cost.collective_bytes,
        collective_by_kind=cost.collective_summary(),
        while_trips=cost.while_trips,
        unknown_trip_whiles=cost.unknown_trip_whiles,
        t_compute=terms.t_compute,
        t_memory=terms.t_memory,
        t_collective=terms.t_collective,
        bottleneck=terms.bottleneck,
        model_flops=terms.model_flops,
        useful_ratio=terms.useful_ratio,
        roofline_fraction=terms.roofline_fraction,
        t_bound=terms.t_bound,
    )
    return rec


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             out_dir: str, verbose: bool = True,
             train_overrides: Optional[dict] = None) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    lowered, compiled, meta = lower_cell(
        arch, shape_name, multi_pod=multi_pod,
        train_overrides=train_overrides)
    rec = analyze_cell(compiled, meta, cfg, shape)
    os.makedirs(out_dir, exist_ok=True)
    tag = "multipod" if multi_pod else "pod"
    path = os.path.join(out_dir,
                        f"{arch}_{shape_name}_{tag}.json".replace("/", "-"))
    with open(path, "w") as f:
        json.dump(rec, f, indent=1)
    if verbose:
        ma = rec["memory_analysis"]
        print(f"[dryrun] {arch} {shape_name} {rec['mesh']}: "
              f"compile {rec['t_compile_s']}s  "
              f"mem/dev={rec['bytes_per_device'] / 2**30:.2f} GiB "
              f"(args {ma.get('argument_size_in_bytes', 0) / 2**30:.2f} "
              f"temp {ma.get('temp_size_in_bytes', 0) / 2**30:.2f})")
        print(f"  flops/dev={rec['hlo_flops']:.3e} "
              f"bytes/dev={rec['hlo_bytes']:.3e} "
              f"coll/dev={rec['collective_bytes']:.3e} "
              f"{rec['collective_by_kind']}")
        print(f"  C={rec['t_compute'] * 1e3:.2f}ms M={rec['t_memory'] * 1e3:.2f}ms "
              f"X={rec['t_collective'] * 1e3:.2f}ms -> {rec['bottleneck']} "
              f"useful={rec['useful_ratio']:.3f} "
              f"roofline={rec['roofline_fraction']:.3f}")
    del lowered, compiled
    gc.collect()
    return rec


# ---------------------------------------------------------------------------
# Drivers
# ---------------------------------------------------------------------------


def all_cells():
    for arch in ALL_ARCHS:
        cfg = get_config(arch)
        for shape_name in cfg.shapes():
            yield arch, shape_name


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.launch.dryrun",
        description="multi-pod AOT dry-run (lower+compile, no allocation)")
    ap.add_argument("--arch")
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod"])
    ap.add_argument("--all", action="store_true",
                    help="run every cell (subprocess per cell)")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--out", default=DEFAULT_OUT)
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args(argv)

    if args.list:
        for arch, shape in all_cells():
            print(arch, shape)
        return 0

    if args.all:
        failures = []
        for arch, shape in all_cells():
            for mesh_kind in ("pod", "multipod"):
                tag = f"{arch}_{shape}_{mesh_kind}".replace("/", "-")
                path = os.path.join(args.out, tag + ".json")
                if args.skip_existing and os.path.exists(path):
                    print(f"[skip] {tag}")
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape,
                       "--mesh", mesh_kind, "--out", args.out]
                print(f"[run ] {tag}", flush=True)
                r = subprocess.run(cmd)
                if r.returncode != 0:
                    failures.append(tag)
        if failures:
            print("FAILED cells:", failures)
            return 1
        print("all cells OK")
        return 0

    if not args.arch or not args.shape:
        ap.error("--arch and --shape required (or --all / --list)")
    run_cell(args.arch, args.shape, multi_pod=(args.mesh == "multipod"),
             out_dir=args.out)
    return 0


if __name__ == "__main__":
    sys.exit(main())
