"""Serving driver: batched prefill+decode with MEMSCOPE-placed KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b \
        --reduced --batch 4 --prompt-len 32 --new-tokens 32
"""
from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ServeConfig, get_config
from repro.core.characterize import characterize
from repro.core.coordinator import CoreCoordinator
from repro.core.placement import PlacementAdvisor
from repro.launch.mesh import describe, make_host_mesh
from repro.models import lm
from repro.parallel.sharding import make_rules
from repro.serve.engine import ServeEngine, cache_bytes


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.launch.serve")
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--kv-placement", default="auto",
                    choices=["auto", "hbm", "host"])
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_host_mesh(args.data, args.model)
    rules = make_rules(cfg, mesh, global_batch=args.batch,
                       shape_kind="decode")

    # MEMSCOPE: characterize, then let the advisor place the KV cache
    coord = CoreCoordinator(backend="simulate")
    db = characterize(coord, pools=["hbm", "host"],
                      obs_strategies=("r", "l"), stress_strategies=("w",),
                      iters=10)
    advisor = PlacementAdvisor(db, coord.platform)

    params = lm.init_params(cfg, jax.random.PRNGKey(args.seed))
    engine = ServeEngine(cfg, params, rules,
                         ServeConfig(kv_placement=args.kv_placement),
                         advisor=advisor, pool_mgr=coord.pools)

    max_len = args.prompt_len + args.new_tokens
    kv_bytes = cache_bytes(cfg, args.batch, max_len)
    print(f"[serve] arch={cfg.name} mesh={describe(mesh)} "
          f"kv_cache={kv_bytes / 2**20:.2f} MiB")

    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(rng.integers(
        0, cfg.vocab_size, (args.batch, args.prompt_len), dtype=np.int32))
    frontend = None
    if cfg.frontend == "vlm":
        frontend = {"prefix_embeds": jnp.asarray(rng.standard_normal(
            (args.batch, cfg.n_prefix_embeds, cfg.d_model),
            dtype=np.float32) * 0.02)}
    elif cfg.frontend == "audio":
        frontend = {"frame_embeds": jnp.asarray(rng.standard_normal(
            (args.batch, args.prompt_len, cfg.d_model),
            dtype=np.float32) * 0.02)}

    t0 = time.time()
    out = engine.generate(prompts, max_new_tokens=args.new_tokens,
                          temperature=args.temperature, seed=args.seed,
                          frontend=frontend)
    jax.block_until_ready(out.tokens)
    wall = time.time() - t0
    total_new = args.batch * args.new_tokens
    print(f"[serve] kv_pool={out.kv_pool} "
          f"{total_new} tokens in {wall:.2f}s "
          f"({total_new / wall:.1f} tok/s incl. compile)")
    print(f"[serve] sample: {np.asarray(out.tokens[0, :16]).tolist()}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
