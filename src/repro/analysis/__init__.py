"""Post-compile analysis: while-aware HLO cost parser + roofline report."""
