"""Roofline terms from a compiled dry-run (per DESIGN.md §7).

Three terms, all **seconds per step, per device** (the SPMD program is
identical on every device, so per-device == per-step wall time at the
modeled peak):

  compute    = device_FLOPs / peak_FLOPs
  memory     = device_bytes / HBM_bw
  collective = device_collective_bytes / ICI_bw

Inputs are the while-aware HLO parse (``repro.analysis.hlo``) of the
post-SPMD module — NOT ``cost_analysis()``, which undercounts scanned
layers (the whole point of the parser).  ``model_flops_*`` provide the
"useful work" yardstick: MODEL_FLOPS/HLO_FLOPs < 1 exposes remat
recompute and redundancy; > 1 means the compiler found shortcuts (or the
parser missed something — investigate either way).
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field
from typing import Dict, List, Optional

from repro.analysis.hlo import HloCost
from repro.configs.base import ModelConfig, ShapeSpec

# ---------------------------------------------------------------------------
# TPU v5e hardware constants (per chip) — the assignment's numbers.
# ---------------------------------------------------------------------------

PEAK_FLOPS = 197e12          # bf16 FLOP/s
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s per link (2D torus: ~2 usable
N_ICI_LINKS = 2              # concurrent links per chip for ring phases)


@dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    n_devices: int
    # per-device program cost (while-aware parse)
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float
    collective_by_kind: Dict[str, float]
    # terms (seconds)
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0
    # usefulness
    model_flops: float = 0.0          # global, analytic
    useful_ratio: float = 0.0         # model_flops / (hlo_flops * devices)
    # memory picture
    bytes_per_device: int = 0         # allocation (args+temp+out)
    # bookkeeping
    unknown_trip_whiles: int = 0
    note: str = ""

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        """Roofline step time (perfect overlap of the three engines)."""
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute time / bound time: 1.0 = MXU-saturated with zero
        overhead.  The score we hillclimb."""
        if self.t_bound <= 0:
            return 0.0
        t_useful = (self.model_flops / max(self.n_devices, 1)) / PEAK_FLOPS
        return t_useful / self.t_bound

    def row(self) -> str:
        return (f"{self.arch:22s} {self.shape:12s} {self.mesh:10s} "
                f"C={self.t_compute * 1e3:9.2f}ms "
                f"M={self.t_memory * 1e3:9.2f}ms "
                f"X={self.t_collective * 1e3:9.2f}ms "
                f"-> {self.bottleneck:10s} "
                f"useful={self.useful_ratio:6.3f} "
                f"roofline={self.roofline_fraction:6.3f}")


def model_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """6·N·D (train) or 2·N_active·D (one forward token batch)."""
    n_active = cfg.n_active_params()
    if shape.kind == "train":
        return 6.0 * n_active * shape.tokens
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.tokens
    # decode: one token per sequence per step
    return 2.0 * n_active * shape.global_batch


# Read share of HBM line-touches per workload kind, for the surface's
# rw_ratio axis.  Train streams parameters+activations forward and
# writes gradients/optimizer state back (~2 reads per write); prefill
# reads weights and writes the full KV prefix; decode reads the whole
# cache + weights every token and writes a single KV slot.
WORKLOAD_RW_MIX = {"train": 2.0 / 3.0, "prefill": 0.75, "decode": 0.9}


def workload_rw_mix(shape) -> float:
    """The ``rw_ratio`` surface coordinate of a workload
    :class:`~repro.configs.base.ShapeSpec` (by its ``kind``)."""
    return WORKLOAD_RW_MIX.get(getattr(shape, "kind", ""), 2.0 / 3.0)


def effective_hbm_bw(curve_db, *, n_stressors: int = 0,
                     stress_pool: str = "hbm", stress_strategy: str = "w",
                     shape_tag: str = "",
                     rw_ratio: Optional[float] = None,
                     inject_rate: Optional[float] = None) -> float:
    """HBM bandwidth under characterized contention, bytes/s.

    Consumes a CurveDB (v1/v2/v3): the roofline's memory term is only
    honest under load if it uses the *effective* bandwidth the
    characterization measured, not the datasheet peak.  On a v3
    surface database pass ``rw_ratio`` — e.g.
    ``workload_rw_mix(shape)`` for the workload's actual read/write
    mix — and ``inject_rate`` to interpolate the surface at the
    workload's real traffic coordinates."""
    bw_gbps = curve_db.effective_bw(
        "hbm", n_stressors, stress_pool=stress_pool,
        stress_strat=stress_strategy, shape_tag=shape_tag,
        rw_ratio=rw_ratio, inject_rate=inject_rate)
    return bw_gbps * 1e9


def compute_terms(
    cost: HloCost,
    *,
    cfg: ModelConfig,
    shape: ShapeSpec,
    mesh_desc: str,
    n_devices: int,
    bytes_per_device: int = 0,
    note: str = "",
    hbm_bw: Optional[float] = None,     # e.g. effective_hbm_bw(curve_db)
) -> RooflineTerms:
    mf = model_flops(cfg, shape)
    total_hlo_flops = cost.flops * n_devices
    mem_bw = hbm_bw if hbm_bw else HBM_BW
    t = RooflineTerms(
        arch=cfg.name, shape=shape.name, mesh=mesh_desc,
        n_devices=n_devices,
        hlo_flops=cost.flops,
        hlo_bytes=cost.bytes,
        collective_bytes=cost.collective_bytes,
        collective_by_kind=cost.collective_summary(),
        t_compute=cost.flops / PEAK_FLOPS,
        t_memory=cost.bytes / mem_bw,
        t_collective=cost.collective_bytes / (ICI_BW * N_ICI_LINKS),
        model_flops=mf,
        useful_ratio=(mf / total_hlo_flops) if total_hlo_flops else 0.0,
        bytes_per_device=bytes_per_device,
        unknown_trip_whiles=len(cost.unknown_trip_whiles),
        note=note,
    )
    return t


# ---------------------------------------------------------------------------
# Persistence for the experiment log
# ---------------------------------------------------------------------------


def save_terms(terms: RooflineTerms, path: str) -> None:
    with open(path, "w") as f:
        d = asdict(terms)
        d["bottleneck"] = terms.bottleneck
        d["t_bound"] = terms.t_bound
        d["roofline_fraction"] = terms.roofline_fraction
        json.dump(d, f, indent=1)


def load_terms(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def table(rows: List[dict]) -> str:
    """EXPERIMENTS.md §Roofline table from saved dicts."""
    hdr = (f"| arch | shape | mesh | compute (ms) | memory (ms) | "
           f"collective (ms) | bottleneck | MODEL/HLO | roofline |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for d in rows:
        lines.append(
            f"| {d['arch']} | {d['shape']} | {d['mesh']} "
            f"| {d['t_compute'] * 1e3:.2f} | {d['t_memory'] * 1e3:.2f} "
            f"| {d['t_collective'] * 1e3:.2f} | {d['bottleneck']} "
            f"| {d['useful_ratio']:.3f} | {d['roofline_fraction']:.3f} |")
    return "\n".join(lines)
