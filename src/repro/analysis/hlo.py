"""While-aware HLO cost parser.

XLA's compiled-program cost analysis counts each ``while`` body **once**,
but our
models deliberately scan over layer periods / microbatches / q-chunks to
keep the HLO small (see models/blocks.py) — so XLA's numbers can be off
by the total trip-count product (e.g. 34 layers x 8 microbatches).  This
module re-derives the three roofline inputs directly from the
post-optimization, post-SPMD HLO text:

* ``flops``            — 2*M*N*K per dot (parsed dimension numbers),
                         multiplied through while-loop trip counts;
* ``bytes``            — operand+output bytes of every top-level
                         instruction (fusions count their real in/outs,
                         not their internals), while-multiplied;
* ``collective_bytes`` — operand bytes of all-gather / all-reduce /
                         reduce-scatter / all-to-all / collective-permute
                         (+ their -start variants), while-multiplied,
                         with per-op detail retained for diagnosis.

Trip counts are recovered from the canonical XLA while pattern: the
condition computation compares the induction variable against a
constant with direction=LT (lax.scan / fori_loop always lower to this).
Everything is **per device**: the input is the SPMD-partitioned module.

The parser is intentionally text-based: it must work on any backend
(the CPU container included) and on modules too big to re-trace.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

# ---------------------------------------------------------------------------
# Shapes
# ---------------------------------------------------------------------------

DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1, "f8e4m3": 1, "f8e5m2fnuz": 1,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def parse_shape(text: str) -> List[Tuple[str, Tuple[int, ...]]]:
    """All array shapes in a (possibly tuple) shape string."""
    out = []
    for m in _SHAPE_RE.finditer(text):
        dt = m.group(1)
        if dt not in DTYPE_BYTES:
            continue
        dims = tuple(int(d) for d in m.group(2).split(",") if d)
        out.append((dt, dims))
    return out


def shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in parse_shape(text):
        n = 1
        for d in dims:
            n *= d
        total += n * DTYPE_BYTES[dt]
    return total


def _num_elements(dims: Tuple[int, ...]) -> int:
    n = 1
    for d in dims:
        n *= d
    return n


# ---------------------------------------------------------------------------
# Instruction / computation model
# ---------------------------------------------------------------------------


@dataclass
class Instr:
    name: str
    shape_text: str        # full result-shape text (may be a tuple)
    opcode: str
    operands: List[str]
    attrs: str             # raw text after the operand list
    line: str


@dataclass
class Computation:
    name: str
    instrs: Dict[str, Instr] = field(default_factory=dict)
    order: List[str] = field(default_factory=list)

    def add(self, ins: Instr) -> None:
        self.instrs[ins.name] = ins
        self.order.append(ins.name)


# one HLO instruction line:  [ROOT] %name = <shape> opcode(...), attrs
_INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\(.*?\))|[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?)\s*"
    r"([\w\-]+)\((.*)$")
_COMP_NAME_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")


def _split_operands(argtext: str) -> Tuple[List[str], str]:
    """Split 'a, b, c), attr=...' into operand names + trailing attrs."""
    depth = 0
    for i, ch in enumerate(argtext):
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            if depth == 0:
                ops_text, attrs = argtext[:i], argtext[i + 1:]
                break
            depth -= 1
    else:
        ops_text, attrs = argtext, ""
    ops = []
    depth = 0
    cur = ""
    for ch in ops_text:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            ops.append(cur.strip())
            cur = ""
        else:
            cur += ch
    if cur.strip():
        ops.append(cur.strip())
    names = []
    for o in ops:
        m = re.search(r"%([\w.\-]+)\s*$", o)
        names.append(m.group(1) if m else o)
    return names, attrs


def parse_module(hlo_text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        # computation header: `[ENTRY] %name (params...) -> shape {` at
        # column 0 (instructions are indented; /*index=N*/ comments inside
        # tuple params mean we cannot key on '=' absence)
        if not raw[:1].isspace() and line.endswith("{") and "->" in line:
            mc = _COMP_NAME_RE.match(line)
            if mc:
                cur = Computation(mc.group(1))
                comps[cur.name] = cur
                continue
        if line.strip() == "}":
            # end of computation (module braces have no '-> ... {' header)
            cur = None if cur is not None else cur
            continue
        if cur is None:
            continue
        mi = _INSTR_RE.match(line)
        if not mi:
            continue
        name, shape_text, opcode, rest = mi.groups()
        operands, attrs = _split_operands(rest)
        cur.add(Instr(name, shape_text, opcode, operands, attrs, line))
    return comps


# ---------------------------------------------------------------------------
# Trip counts
# ---------------------------------------------------------------------------


def _const_value(ins: Instr) -> Optional[int]:
    m = re.search(r"constant\((-?\d+)\)", ins.line)
    return int(m.group(1)) if m else None


_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')


def trip_count(while_ins: Instr, cond: Optional[Computation]) -> Optional[int]:
    """XLA records `backend_config={"known_trip_count":{"n":N}}` on the
    while op for counted loops (every lax.scan/fori_loop).  Fall back to
    the condition-computation `compare(i, constant(N)), direction=LT`
    pattern (possibly wrapped in a kLoop fusion)."""
    m = _TRIP_RE.search(while_ins.attrs)
    if m:
        return int(m.group(1))
    if cond is None:
        return None
    for nm in cond.order:
        ins = cond.instrs[nm]
        if ins.opcode == "compare" and "direction=LT" not in ins.attrs:
            continue
        if ins.opcode not in ("compare", "fusion"):
            continue
        for op in ins.operands:
            src = cond.instrs.get(op)
            if src is None:
                continue
            if src.opcode == "constant":
                v = _const_value(src)
                if v is not None:
                    return v
            # constant may be forwarded through a copy/convert
            if src.opcode in ("copy", "convert") and src.operands:
                src2 = cond.instrs.get(src.operands[0])
                if src2 is not None and src2.opcode == "constant":
                    v = _const_value(src2)
                    if v is not None:
                        return v
    return None


# ---------------------------------------------------------------------------
# Cost walk
# ---------------------------------------------------------------------------

COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

#: opcodes that move no HBM bytes of their own
_FREE_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "iota", "partition-id", "replica-id", "rng-get-and-update-state",
    "custom-call", "opt-barrier", "domain", "add-dependency",
    "get-dimension-size",
}

_CALL_ATTRS = ("to_apply", "calls", "body", "condition", "branch_computations",
               "called_computations")


@dataclass
class CollectiveOp:
    kind: str
    bytes_per_call: int
    group_size: int
    trips: int
    name: str

    @property
    def total_bytes(self) -> int:
        return self.bytes_per_call * self.trips


#: ops that only re-arrange or re-type data.  The CPU backend legalizes
#: bf16 dots by upconverting operands to f32 and copy-transposing them to
#: the dot's preferred layout; on the TPU target the MXU consumes bf16 in
#: either layout, so this traffic does not exist.  Fusions made ONLY of
#: these ops are tallied in ``movement_bytes`` (reported separately as a
#: host-compile artifact), not in the memory-roofline ``bytes``.
_MOVEMENT_OPS = {"parameter", "constant", "copy", "convert", "bitcast",
                 "transpose", "reshape", "tuple", "get-tuple-element"}


@dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    movement_bytes: float = 0.0      # layout/dtype-only traffic (see above)
    collectives: List[CollectiveOp] = field(default_factory=list)
    while_trips: Dict[str, int] = field(default_factory=dict)
    unknown_trip_whiles: List[str] = field(default_factory=list)

    def collective_summary(self) -> Dict[str, float]:
        out: Dict[str, float] = {}
        for c in self.collectives:
            k = c.kind.replace("-start", "")
            out[k] = out.get(k, 0.0) + c.total_bytes
        return out


def _group_size(attrs: str) -> int:
    # iota form: replica_groups=[G,S]<=[N]  -> group size S
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=\[", attrs)
    if m:
        return int(m.group(2))
    # explicit form: replica_groups={{0,1,2,3},...}
    m = re.search(r"replica_groups=\{\{([0-9, ]+)\}", attrs)
    if m:
        return len(m.group(1).split(","))
    return 1


def _dot_flops(ins: Instr, comp: Computation) -> float:
    out_elems = sum(_num_elements(d) for _, d in parse_shape(ins.shape_text))
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.attrs)
    k = 1
    if m and ins.operands:
        lhs = comp.instrs.get(ins.operands[0])
        if lhs is not None:
            shp = parse_shape(lhs.shape_text)
            if shp:
                dims = shp[0][1]
                for ci in (int(x) for x in m.group(1).split(",") if x):
                    if ci < len(dims):
                        k *= dims[ci]
    return 2.0 * out_elems * k


def _conv_flops(ins: Instr, comp: Computation) -> float:
    out_elems = sum(_num_elements(d) for _, d in parse_shape(ins.shape_text))
    k = 1
    if len(ins.operands) >= 2:
        rhs = comp.instrs.get(ins.operands[1])
        if rhs is not None:
            shp = parse_shape(rhs.shape_text)
            if shp:
                # kernel: spatial dims x input feature; output feature excluded
                dims = shp[0][1]
                k = _num_elements(dims) // max(1, dims[-1])
    return 2.0 * out_elems * k


def _operand_bytes(ins: Instr, comp: Computation, idx: int) -> int:
    if idx >= len(ins.operands):
        return 0
    src = comp.instrs.get(ins.operands[idx])
    return shape_bytes(src.shape_text) if src is not None else 0


def _instr_bytes(ins: Instr, comp: Computation) -> int:
    """HBM bytes actually moved by one instruction.

    Slice-family ops touch only the slice, not the whole operand — a
    dynamic-slice of scan-stacked layer params reads ONE layer per trip,
    and a decode-step dynamic-update-slice writes one token row of the KV
    cache, not the cache.  Counting full operands there would inflate the
    memory term by the layer count (and it did, before this existed)."""
    op = ins.opcode
    out = shape_bytes(ins.shape_text)
    if op in ("dynamic-slice", "slice", "gather"):
        return 2 * out                      # read slice + write result
    if op == "dynamic-update-slice":
        upd = _operand_bytes(ins, comp, 1)
        return 2 * upd                      # read update + write region
    if op == "scatter":
        upd = _operand_bytes(ins, comp, 2)
        return 2 * upd
    if op in ("broadcast", "iota"):
        return out                          # reads negligible
    total = out
    for i in range(len(ins.operands)):
        total += _operand_bytes(ins, comp, i)
    return total


def _fusion_root(sub: Computation) -> Optional[Instr]:
    return sub.instrs.get(sub.order[-1]) if sub.order else None


def _fusion_bytes(ins: Instr, comp: Computation, sub: Computation) -> int:
    """HBM bytes for a fusion, from how each *parameter* is used inside.

    A parameter consumed only through dynamic-slice/slice/gather is read
    only slice-by-slice (the scan-over-stacked-layers pattern); one that
    is the target of a root dynamic-update-slice is updated in place
    (the KV-cache-append pattern) — counting those operands at full size
    inflates the memory term by the layer count.
    """
    # parameter name -> fusion operand position
    param_pos: Dict[str, int] = {}
    for nm in sub.order:
        p = sub.instrs[nm]
        if p.opcode == "parameter":
            try:
                param_pos[nm] = int(p.operands[0]) if p.operands else 0
            except ValueError:
                pass

    reads: Dict[str, int] = {nm: 0 for nm in param_pos}
    full: Dict[str, bool] = {nm: False for nm in param_pos}
    for nm in sub.order:
        q = sub.instrs[nm]
        if q.opcode == "parameter":
            continue
        for pos, opnd in enumerate(q.operands):
            if opnd not in param_pos:
                continue
            if q.opcode in ("dynamic-slice", "slice", "gather") and pos == 0:
                reads[opnd] += shape_bytes(q.shape_text)
            elif q.opcode == "dynamic-update-slice" and pos == 0:
                pass                      # in-place target: write-counted below
            else:
                full[opnd] = True

    total = 0
    for nm, pos in param_pos.items():
        if full[nm]:
            total += _operand_bytes(ins, comp, pos)
        else:
            total += reads[nm]

    # writes: root DUS writes the update region, anything else the output.
    # We look THROUGH convert/copy/bitcast roots: the CPU backend
    # legalizes bf16 dots via f32, hoisting a whole-buffer convert out of
    # scan loops and re-converting the full stack per iteration — on the
    # TPU target (native bf16 MXU) the convert does not exist, so
    # counting it would charge the roofline for a host-only artifact.
    root = _fusion_root(sub)
    for _ in range(3):
        if root is not None and root.opcode in ("convert", "copy",
                                                "bitcast") and root.operands:
            root = sub.instrs.get(root.operands[0])
        else:
            break
    if root is not None and root.opcode == "dynamic-update-slice":
        upd = 0
        if len(root.operands) > 1 and root.operands[1] in sub.instrs:
            upd = shape_bytes(sub.instrs[root.operands[1]].shape_text)
        total += 2 * upd                  # read update + write region
    else:
        total += shape_bytes(ins.shape_text)
    return total


def _fusion_dot_flops(comp: Computation, comps: Dict[str, Computation]) -> float:
    """dots/convs inside a fused computation still execute — count them."""
    fl = 0.0
    for nm in comp.order:
        ins = comp.instrs[nm]
        if ins.opcode == "dot":
            fl += _dot_flops(ins, comp)
        elif ins.opcode == "convolution":
            fl += _conv_flops(ins, comp)
        elif ins.opcode == "fusion":
            sub = _called(ins, ("calls",), comps)
            if sub:
                fl += _fusion_dot_flops(sub[0], comps)
    return fl


def _called(ins: Instr, keys, comps: Dict[str, Computation]
            ) -> List[Computation]:
    out = []
    for key in keys:
        for m in re.finditer(key + r"=%?([\w.\-]+)", ins.attrs):
            c = comps.get(m.group(1))
            if c is not None:
                out.append(c)
        m = re.search(key + r"=\{([^}]*)\}", ins.attrs)
        if m:
            for nm in m.group(1).split(","):
                c = comps.get(nm.strip().lstrip("%"))
                if c is not None:
                    out.append(c)
    return out


def analyze(hlo_text: str, entry: Optional[str] = None) -> HloCost:
    comps = parse_module(hlo_text)
    if not comps:
        return HloCost()
    if entry is None:
        m = re.search(r"ENTRY\s+%?([\w.\-]+)", hlo_text)
        entry = m.group(1) if m else next(iter(comps))
    cost = HloCost()
    _walk(comps[entry], comps, 1, cost, seen=set())
    return cost


def _walk(comp: Computation, comps: Dict[str, Computation], mult: int,
          cost: HloCost, seen: set) -> None:
    for nm in comp.order:
        ins = comp.instrs[nm]
        op = ins.opcode
        if op == "while":
            bodies = _called(ins, ("body",), comps)
            conds = _called(ins, ("condition",), comps)
            trips = trip_count(ins, conds[0] if conds else None)
            if trips is None:
                trips = 1
                cost.unknown_trip_whiles.append(ins.name)
            cost.while_trips[ins.name] = trips
            if bodies:
                _walk(bodies[0], comps, mult * trips, cost, seen)
            if conds:
                _walk(conds[0], comps, mult * trips, cost, seen)
            continue
        if op == "conditional":
            branches = _called(ins, ("branch_computations",
                                     "true_computation",
                                     "false_computation"), comps)
            for b in branches:       # upper bound: all branches counted once
                _walk(b, comps, mult, cost, seen)
            continue
        if op in ("call", "async-start"):
            for c in _called(ins, ("to_apply", "called_computations",
                                   "calls"), comps):
                _walk(c, comps, mult, cost, seen)
            continue
        base = op.replace("-start", "")
        if base in COLLECTIVES:
            nbytes = 0
            for o in ins.operands:
                src = comp.instrs.get(o)
                if src is not None:
                    nbytes += shape_bytes(src.shape_text)
            if nbytes == 0:          # operand defined elsewhere: use result
                nbytes = shape_bytes(ins.shape_text)
            if op.endswith("-done"):
                continue
            cop = CollectiveOp(kind=base, bytes_per_call=nbytes,
                               group_size=_group_size(ins.attrs),
                               trips=mult, name=ins.name)
            cost.collectives.append(cop)
            cost.collective_bytes += cop.total_bytes
            continue
        if op in _FREE_OPS or op.endswith("-done"):
            continue
        if op == "fusion":
            subs = _called(ins, ("calls",), comps)
            if subs:
                cost.flops += mult * _fusion_dot_flops(subs[0], comps)
                b = mult * _fusion_bytes(ins, comp, subs[0])
                if all(q.opcode in _MOVEMENT_OPS
                       for q in subs[0].instrs.values()):
                    cost.movement_bytes += b
                else:
                    cost.bytes += b
            else:
                cost.bytes += mult * _instr_bytes(ins, comp)
            continue
        if op == "dot":
            cost.flops += mult * _dot_flops(ins, comp)
            cost.bytes += mult * _instr_bytes(ins, comp)
            continue
        if op == "convolution":
            cost.flops += mult * _conv_flops(ins, comp)
            cost.bytes += mult * _instr_bytes(ins, comp)
            continue
        if op in ("transpose", "convert", "reshape"):
            cost.movement_bytes += mult * _instr_bytes(ins, comp)
            continue
        # generic data-moving op (copy, reduce, select, ...)
        cost.bytes += mult * _instr_bytes(ins, comp)
