"""Dry-run profiler: attribute parsed bytes/flops/collectives to model
code via HLO ``op_name`` metadata.

This is the §Perf loop's "profile": for a compiled cell it reports the
top-N instructions by (while-multiplied) bytes, grouped by the JAX
op_name path (e.g. ``jit(step)/while/body/.../bqkgh,bskh->bkgqs``), so a
hypothesis can name the exact model-code line to change.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, List, Optional, Tuple

from repro.analysis import hlo as H

_OPNAME_RE = re.compile(r'op_name="([^"]+)"')


def _op_name(ins: H.Instr) -> str:
    m = _OPNAME_RE.search(ins.line)
    if not m:
        return f"<{ins.opcode}>"
    name = m.group(1)
    # strip jit wrapper and trailing uniquifiers for grouping
    name = re.sub(r"^jit\([^)]*\)/", "", name)
    return name


def profile(hlo_text: str, top: int = 25) -> Dict:
    comps = H.parse_module(hlo_text)
    m = re.search(r"ENTRY\s+%?([\w.\-]+)", hlo_text)
    entry = m.group(1) if m else next(iter(comps))

    by_name_bytes: Dict[str, float] = defaultdict(float)
    by_name_flops: Dict[str, float] = defaultdict(float)
    coll_rows: List[Tuple[float, str, str]] = []

    def walk(comp: H.Computation, mult: int):
        for nm in comp.order:
            ins = comp.instrs[nm]
            op = ins.opcode
            if op == "while":
                bodies = H._called(ins, ("body",), comps)
                conds = H._called(ins, ("condition",), comps)
                trips = H.trip_count(ins, conds[0] if conds else None) or 1
                if bodies:
                    walk(bodies[0], mult * trips)
                continue
            if op in ("call", "conditional", "async-start"):
                for c in H._called(ins, ("to_apply", "called_computations",
                                          "calls", "branch_computations",
                                          "true_computation",
                                          "false_computation"), comps):
                    walk(c, mult)
                continue
            base = op.replace("-start", "")
            if base in H.COLLECTIVES:
                if op.endswith("-done"):
                    continue
                nbytes = 0
                for o in ins.operands:
                    src = comp.instrs.get(o)
                    if src is not None:
                        nbytes += H.shape_bytes(src.shape_text)
                nbytes = nbytes or H.shape_bytes(ins.shape_text)
                coll_rows.append((float(nbytes * mult), base, _op_name(ins)))
                continue
            if op in H._FREE_OPS or op.endswith("-done"):
                continue
            if op == "fusion":
                subs = H._called(ins, ("calls",), comps)
                b = H._fusion_bytes(ins, comp, subs[0]) if subs \
                    else H._instr_bytes(ins, comp)
                f = H._fusion_dot_flops(subs[0], comps) if subs else 0.0
            elif op == "dot":
                b = H._instr_bytes(ins, comp)
                f = H._dot_flops(ins, comp)
            else:
                b = H._instr_bytes(ins, comp)
                f = 0.0
            key = _op_name(ins)
            by_name_bytes[key] += float(b * mult)
            by_name_flops[key] += float(f * mult)

    walk(comps[entry], 1)
    coll_rows.sort(reverse=True)
    return {
        "bytes_by_site": sorted(by_name_bytes.items(),
                                key=lambda kv: -kv[1])[:top],
        "flops_by_site": sorted(by_name_flops.items(),
                                key=lambda kv: -kv[1])[:top],
        "collectives": coll_rows[:top],
        "total_bytes": sum(by_name_bytes.values()),
        "total_flops": sum(by_name_flops.values()),
        "total_collective_bytes": sum(r[0] for r in coll_rows),
    }


def render(p: Dict, top: int = 20) -> str:
    out = []
    out.append(f"total: {p['total_flops']:.3e} flops, "
               f"{p['total_bytes'] / 2**30:.2f} GiB moved, "
               f"{p['total_collective_bytes'] / 2**30:.2f} GiB collective")
    out.append("\n-- top sites by bytes --")
    for name, b in p["bytes_by_site"][:top]:
        out.append(f"{b / 2**30:9.2f} GiB  {name[:110]}")
    out.append("\n-- top sites by flops --")
    for name, f in p["flops_by_site"][:top]:
        out.append(f"{f:9.3e}      {name[:110]}")
    out.append("\n-- top collectives --")
    for b, kind, name in p["collectives"][:top]:
        out.append(f"{b / 2**30:9.3f} GiB  {kind:18s} {name[:90]}")
    return "\n".join(out)


def profile_cell(arch: str, shape: str, *, multi_pod: bool = False,
                 top: int = 20, train_overrides: Optional[dict] = None
                 ) -> str:
    """Lower+compile one cell and render its profile (dry-run only)."""
    from repro.launch.dryrun import lower_cell
    lowered, compiled, meta = lower_cell(arch, shape, multi_pod=multi_pod,
                                         train_overrides=train_overrides)
    return render(profile(compiled.as_text(), top=top), top=top)
