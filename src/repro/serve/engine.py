"""Serving engine: chunked prefill + scanned decode with placed KV caches.

The KV cache is a first-class *placeable object*: the engine sizes it
from the model config, asks the MEMSCOPE :class:`PlacementAdvisor` which
pool it belongs in under the expected contention (HBM normally; host DRAM
when HBM capacity is the binding constraint — the long-context regime),
and materialises it through the chosen upool.  This is the paper's
Fig. 14 loop (characterize -> place -> run) applied to an inference
server.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ServeConfig
from repro.models import lm
from repro.parallel.sharding import ShardingRules
from repro.train.step import make_constrain

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Cache sizing / placement
# ---------------------------------------------------------------------------


def cache_bytes(cfg: ModelConfig, batch: int, max_len: int,
                kv_dtype=jnp.bfloat16) -> int:
    import math
    struct = lm.cache_struct(cfg, batch, max_len, kv_dtype)
    return sum(int(s.dtype.itemsize) * math.prod(s.shape)
               for s in jax.tree.leaves(struct))


def decode_rw_mix(batch: int, max_len: int) -> float:
    """Read share of the decode step's KV traffic (the ``rw_ratio``
    surface coordinate).  Each generated token reads the whole cache
    prefix — ``max_len`` positions per sequence — and writes exactly
    one new slot, so the mix approaches pure-read as contexts grow."""
    reads = float(max(1, max_len))
    return reads / (reads + 1.0)


def choose_kv_pool(cfg: ModelConfig, batch: int, max_len: int, *,
                   advisor=None, scfg: Optional[ServeConfig] = None,
                   hbm_free_bytes: Optional[int] = None,
                   rw_mix: Optional[float] = None) -> str:
    scfg = scfg or ServeConfig()
    if scfg.kv_placement != "auto":
        return scfg.kv_placement
    if advisor is None:
        return "hbm"
    from repro.core.placement import ContentionSpec, kv_cache_object
    nbytes = cache_bytes(cfg, batch, max_len)
    obj = kv_cache_object("kv", nbytes, bytes_read_per_token=float(nbytes))
    caps = None
    if hbm_free_bytes is not None:
        caps = {"hbm": hbm_free_bytes, "host": 256 << 30}
    # advise at the engine's observed decode traffic coordinates: the
    # surface interpolates its rw_ratio axis at the cache's actual
    # read/write mix instead of a letter-keyed worst case
    if rw_mix is None:
        rw_mix = decode_rw_mix(batch, max_len)
    plan = advisor.advise([obj], ContentionSpec(0, rw_ratio=rw_mix),
                          capacities=caps)
    return plan.pool_of("kv")


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------


def make_prefill_step(cfg: ModelConfig, rules: ShardingRules, *,
                      max_len: int, q_chunk: int = 256):
    cst = make_constrain(rules)

    def prefill(params: Params, tokens, frontend=None):
        hidden, caches, _ = lm.forward(
            params, tokens, cfg=cfg, mode="prefill", frontend=frontend,
            constrain=cst, max_len=max_len, q_chunk=q_chunk)
        logits = lm.unembed_logits(params, hidden[:, -1:], cfg)
        return caches, logits[:, 0]

    return prefill


def make_decode_step(cfg: ModelConfig, rules: ShardingRules):
    cst = make_constrain(rules)

    def decode(params: Params, caches: Params, token, write_pos,
               frontend=None):
        """token: (B, 1) int32; write_pos: scalar int32 (absolute)."""
        hidden, caches, _ = lm.forward(
            params, token, cfg=cfg, mode="decode", caches=caches,
            write_pos=write_pos, frontend=frontend, constrain=cst)
        logits = lm.unembed_logits(params, hidden, cfg)
        return caches, logits[:, 0]

    return decode


def sample_token(logits, key, temperature: float = 0.0):
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(
        key, logits / temperature, axis=-1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------


@dataclass
class GenerateResult:
    tokens: Any                 # (B, T)
    steps: int
    kv_pool: str


class ServeEngine:
    """Batched prefill+decode over a placed KV cache."""

    def __init__(self, cfg: ModelConfig, params: Params,
                 rules: ShardingRules, scfg: Optional[ServeConfig] = None,
                 advisor=None, pool_mgr=None):
        self.cfg = cfg
        self.params = params
        self.rules = rules
        self.scfg = scfg or ServeConfig()
        self.advisor = advisor
        self.pool_mgr = pool_mgr
        self._decode = jax.jit(make_decode_step(cfg, rules),
                               donate_argnums=(1,))

    def _place_caches(self, caches: Params, pool_name: str) -> Params:
        if self.pool_mgr is None or pool_name == "hbm":
            return caches
        upool = self.pool_mgr.upool(pool_name)
        return upool.place(caches)

    def generate(self, tokens, *, max_new_tokens: int = 32,
                 temperature: float = 0.0, seed: int = 0,
                 frontend=None) -> GenerateResult:
        cfg, rules = self.cfg, self.rules
        b, s = tokens.shape
        max_len = s + max_new_tokens
        kv_pool = choose_kv_pool(cfg, b, max_len, advisor=self.advisor,
                                 scfg=self.scfg,
                                 rw_mix=decode_rw_mix(b, max_len))

        prefill = jax.jit(make_prefill_step(cfg, rules, max_len=max_len),
                          static_argnames=())
        caches, logits = prefill(self.params, tokens, frontend)
        caches = self._place_caches(caches, kv_pool)

        key = jax.random.PRNGKey(seed)
        tok = sample_token(logits, key, temperature)[:, None]

        def body(carry, i):
            caches, tok, key = carry
            key, sub = jax.random.split(key)
            caches, logits = self._decode(self.params, caches, tok,
                                          s + i)
            nxt = sample_token(logits, sub, temperature)[:, None]
            return (caches, nxt, key), tok[:, 0]

        # prefill already sampled token 0; decode the remaining N-1
        (caches, last, _), toks = jax.lax.scan(
            body, (caches, tok, key),
            jnp.arange(max_new_tokens - 1, dtype=jnp.int32))
        out = jnp.concatenate(
            [jnp.moveaxis(toks, 0, 1), last], axis=1) \
            if max_new_tokens > 1 else last
        return GenerateResult(out, max_new_tokens, kv_pool)
