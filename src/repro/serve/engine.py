"""Serving engine: chunked prefill + scanned decode with placed KV caches.

The KV cache is a first-class *placeable object*: the engine sizes it
from the model config, asks the MEMSCOPE :class:`PlacementAdvisor` which
pool it belongs in under the expected contention (HBM normally; host DRAM
when HBM capacity is the binding constraint — the long-context regime),
and materialises it through the chosen upool.  This is the paper's
Fig. 14 loop (characterize -> place -> run) applied to an inference
server.

The loop also closes *online*: pass a
:class:`repro.serve.monitor.ServeMonitor` and the engine times every
decode step on a monitored python loop — the watchdog detects contention
drift against the surface's expectation, a resilient background probe
sweep refreshes the drifted cells under ``qualifier="online"``, and the
migration guard moves the live caches (with hysteresis + rollback) when
the refreshed surface flips the advisor's decision.  Every drift event,
probe sweep, migration and rollback lands in :class:`GenerateResult`.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ServeConfig
from repro.models import lm
from repro.parallel.sharding import ShardingRules
from repro.train.step import make_constrain

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Cache sizing / placement
# ---------------------------------------------------------------------------


def cache_bytes(cfg: ModelConfig, batch: int, max_len: int,
                kv_dtype=jnp.bfloat16) -> int:
    import math
    struct = lm.cache_struct(cfg, batch, max_len, kv_dtype)
    return sum(int(s.dtype.itemsize) * math.prod(s.shape)
               for s in jax.tree.leaves(struct))


def decode_rw_mix(batch: int, max_len: int) -> float:
    """Read share of the decode step's KV traffic (the ``rw_ratio``
    surface coordinate).  Each generated token reads the whole cache
    prefix — ``max_len`` positions per sequence — and writes exactly
    one new slot, so the mix approaches pure-read as contexts grow."""
    reads = float(max(1, max_len))
    return reads / (reads + 1.0)


def pool_capacities(advisor, *, pool_mgr=None,
                    hbm_free_bytes: Optional[int] = None,
                    ) -> Optional[Dict[str, int]]:
    """Candidate-pool capacities for the KV placement solve.

    Live accounting first: a pool manager knows what is *actually*
    free (``pool.available`` = capacity - allocated), so a half-full
    HBM constrains the solve instead of its nameplate size.  Without a
    manager the advisor's own platform capacities apply (the advise()
    default), overridden per-pool by ``hbm_free_bytes`` — no pool's
    capacity is ever invented (the seed hard-coded ``host: 256 GiB``).
    """
    caps: Dict[str, int] = {}
    if pool_mgr is not None:
        for p in advisor.pools:
            try:
                caps[p] = pool_mgr.pool(p).available
            except Exception:
                continue            # pool not backed on this platform
    elif hbm_free_bytes is not None:
        caps = {p: advisor.platform.memories[p].size_bytes
                for p in advisor.pools if p in advisor.platform.memories}
    if hbm_free_bytes is not None and ("hbm" in caps or not caps):
        caps["hbm"] = hbm_free_bytes
    return caps or None


def choose_kv_pool(cfg: ModelConfig, batch: int, max_len: int, *,
                   advisor=None, scfg: Optional[ServeConfig] = None,
                   pool_mgr=None,
                   hbm_free_bytes: Optional[int] = None,
                   rw_mix: Optional[float] = None,
                   inject_rate: Optional[float] = None) -> str:
    scfg = scfg or ServeConfig()
    if scfg.kv_placement != "auto":
        return scfg.kv_placement
    if advisor is None:
        return "hbm"
    from repro.core.placement import ContentionSpec, kv_cache_object
    nbytes = cache_bytes(cfg, batch, max_len)
    obj = kv_cache_object("kv", nbytes, bytes_read_per_token=float(nbytes))
    caps = pool_capacities(advisor, pool_mgr=pool_mgr,
                           hbm_free_bytes=hbm_free_bytes)
    # advise at the engine's observed decode traffic coordinates: the
    # surface interpolates its rw_ratio axis at the cache's actual
    # read/write mix (and its inject_rate axis at the engine's observed
    # decode duty cycle) instead of a letter-keyed worst case
    if rw_mix is None:
        rw_mix = decode_rw_mix(batch, max_len)
    plan = advisor.advise(
        [obj], ContentionSpec(0, rw_ratio=rw_mix,
                              inject_rate=inject_rate),
        capacities=caps)
    return plan.pool_of("kv")


# ---------------------------------------------------------------------------
# Step builders
# ---------------------------------------------------------------------------


def make_prefill_step(cfg: ModelConfig, rules: ShardingRules, *,
                      max_len: int, q_chunk: int = 256):
    cst = make_constrain(rules)

    def prefill(params: Params, tokens, frontend=None):
        hidden, caches, _ = lm.forward(
            params, tokens, cfg=cfg, mode="prefill", frontend=frontend,
            constrain=cst, max_len=max_len, q_chunk=q_chunk)
        logits = lm.unembed_logits(params, hidden[:, -1:], cfg)
        return caches, logits[:, 0]

    return prefill


def make_decode_step(cfg: ModelConfig, rules: ShardingRules):
    cst = make_constrain(rules)

    def decode(params: Params, caches: Params, token, write_pos,
               frontend=None):
        """token: (B, 1) int32; write_pos: scalar int32 (absolute)."""
        hidden, caches, _ = lm.forward(
            params, token, cfg=cfg, mode="decode", caches=caches,
            write_pos=write_pos, frontend=frontend, constrain=cst)
        logits = lm.unembed_logits(params, hidden, cfg)
        return caches, logits[:, 0]

    return decode


def sample_token(logits, key, temperature: float = 0.0):
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(
        key, logits / temperature, axis=-1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Engine
# ---------------------------------------------------------------------------


@dataclass
class GenerateResult:
    tokens: Any                 # (B, T)
    steps: int
    kv_pool: str                # the pool the caches ENDED in
    # online-loop provenance (monitored decode only; empty otherwise)
    drift_events: List[Any] = field(default_factory=list)
    migrations: List[Any] = field(default_factory=list)
    probe_sweeps: int = 0


class ServeEngine:
    """Batched prefill+decode over a placed KV cache.

    ``monitor`` (a :class:`repro.serve.monitor.ServeMonitor`) switches
    ``generate`` onto the monitored decode loop: per-step wall timing
    feeds the contention watchdog and the engine applies the monitor's
    migrate/rollback actions to the live caches between steps.  The
    unmonitored path keeps the fused ``lax.scan`` decode loop."""

    def __init__(self, cfg: ModelConfig, params: Params,
                 rules: ShardingRules, scfg: Optional[ServeConfig] = None,
                 advisor=None, pool_mgr=None, monitor=None):
        self.cfg = cfg
        self.params = params
        self.rules = rules
        self.scfg = scfg or ServeConfig()
        self.advisor = advisor
        self.pool_mgr = pool_mgr
        self.monitor = monitor
        self._decode = jax.jit(make_decode_step(cfg, rules),
                               donate_argnums=(1,))
        # jitted prefill per max_len: repeated generate calls at the
        # same shape reuse ONE trace (the seed re-jitted every call)
        self._prefill_cache: Dict[int, Callable] = {}
        # observed decode duty cycle (EWMA across generate calls): the
        # inject_rate coordinate the engine feeds back into placement
        self._duty: Optional[float] = None

    # -- jit caches ----------------------------------------------------------
    def _prefill(self, max_len: int) -> Callable:
        fn = self._prefill_cache.get(max_len)
        if fn is None:
            fn = jax.jit(make_prefill_step(self.cfg, self.rules,
                                           max_len=max_len))
            self._prefill_cache[max_len] = fn
        return fn

    # -- placement -----------------------------------------------------------
    def _place_caches(self, caches: Params, pool_name: str) -> Params:
        """Materialise the cache pytree in ``pool_name`` via its upool.
        With a pool manager every pool goes through ``upool.place`` —
        including "hbm", so a rollback moves host-placed arrays BACK to
        device memory instead of silently leaving them put."""
        if self.pool_mgr is None:
            return caches
        try:
            upool = self.pool_mgr.upool(pool_name)
        except Exception:
            return caches           # pool not backed on this platform
        return upool.place(caches)

    def duty_cycle(self) -> Optional[float]:
        return self._duty

    def _observe_duty(self, busy_s: float, wall_s: float) -> None:
        if wall_s <= 0.0:
            return
        d = min(1.0, busy_s / wall_s)
        self._duty = d if self._duty is None else 0.2 * d + 0.8 * self._duty

    # -- generation ----------------------------------------------------------
    def generate(self, tokens, *, max_new_tokens: int = 32,
                 temperature: float = 0.0, seed: int = 0,
                 frontend=None,
                 on_step: Optional[Callable[[int, str], None]] = None,
                 ) -> GenerateResult:
        cfg, rules = self.cfg, self.rules
        b, s = tokens.shape
        max_len = s + max_new_tokens
        rw_mix = decode_rw_mix(b, max_len)
        kv_pool = choose_kv_pool(cfg, b, max_len, advisor=self.advisor,
                                 scfg=self.scfg, pool_mgr=self.pool_mgr,
                                 rw_mix=rw_mix, inject_rate=self._duty)

        caches, logits = self._prefill(max_len)(self.params, tokens,
                                                frontend)
        caches = self._place_caches(caches, kv_pool)

        key = jax.random.PRNGKey(seed)
        tok = sample_token(logits, key, temperature)[:, None]

        if self.monitor is None and on_step is None:
            return self._generate_scan(caches, tok, key, s,
                                       max_new_tokens, temperature,
                                       kv_pool)
        return self._generate_monitored(caches, tok, key, s, b, max_len,
                                        max_new_tokens, temperature,
                                        kv_pool, rw_mix, on_step)

    def _generate_scan(self, caches, tok, key, s: int,
                       max_new_tokens: int, temperature: float,
                       kv_pool: str) -> GenerateResult:
        def body(carry, i):
            caches, tok, key = carry
            key, sub = jax.random.split(key)
            caches, logits = self._decode(self.params, caches, tok,
                                          s + i)
            nxt = sample_token(logits, sub, temperature)[:, None]
            return (caches, nxt, key), tok[:, 0]

        # prefill already sampled token 0; decode the remaining N-1
        (caches, last, _), toks = jax.lax.scan(
            body, (caches, tok, key),
            jnp.arange(max_new_tokens - 1, dtype=jnp.int32))
        out = jnp.concatenate(
            [jnp.moveaxis(toks, 0, 1), last], axis=1) \
            if max_new_tokens > 1 else last
        return GenerateResult(out, max_new_tokens, kv_pool)

    def _generate_monitored(self, caches, tok, key, s: int, b: int,
                            max_len: int, max_new_tokens: int,
                            temperature: float, kv_pool: str,
                            rw_mix: float, on_step) -> GenerateResult:
        """The python decode loop: token-identical to the scan path
        (same split order, same pre-update emission), with each step
        wall-timed for the watchdog.  ``on_step(abs_step, pool)`` runs
        INSIDE the timed window — it stands in for the external
        contention the step experiences (benchmarks inject load
        there)."""
        mon = self.monitor
        d0 = m0 = r0 = 0
        if mon is not None:
            mon.bind(kv_bytes=cache_bytes(self.cfg, b, max_len),
                     rw_mix=rw_mix, pool=kv_pool,
                     inject_rate=self._duty,
                     capacities=pool_capacities(self.advisor,
                                                pool_mgr=self.pool_mgr)
                     if self.advisor is not None else None)
            kv_pool = mon.pool or kv_pool
            d0 = len(mon.drift_events)
            m0 = len(mon.migrations)
            r0 = len(mon.refreshes)

        emitted: List[Any] = []
        busy_s = 0.0
        t_loop = time.perf_counter()
        for i in range(max_new_tokens - 1):
            key, sub = jax.random.split(key)
            t0 = time.perf_counter()
            if on_step is not None:
                on_step(s + i, kv_pool)
            caches, logits = self._decode(self.params, caches, tok,
                                          s + i)
            logits.block_until_ready()
            wall_s = time.perf_counter() - t0
            busy_s += wall_s
            nxt = sample_token(logits, sub, temperature)[:, None]
            emitted.append(tok[:, 0])
            tok = nxt
            if mon is not None:
                action = mon.on_step(wall_s * 1e9)
                if action is not None:
                    caches = self._place_caches(caches, action.to_pool)
                    kv_pool = action.to_pool
        self._observe_duty(busy_s, time.perf_counter() - t_loop)

        out = jnp.concatenate(
            [jnp.stack(emitted, axis=1), tok], axis=1) \
            if max_new_tokens > 1 else tok
        result = GenerateResult(out, max_new_tokens, kv_pool)
        if mon is not None:
            result.drift_events = list(mon.drift_events[d0:])
            result.migrations = list(mon.migrations[m0:])
            result.probe_sweeps = len(mon.refreshes) - r0
        return result
