"""Serving-time drift monitoring: watchdog -> probe sweep -> migration.

The offline loop (characterize -> place -> run) trusts its surface
forever; real contention drifts.  This module closes the loop *online*,
in three guarded stages, all running inside the serving process:

* :class:`ContentionWatchdog` — per-decode-step wall timing on the
  shared EWMA/median machinery
  (:class:`repro.runtime.fault_tolerance.StragglerMonitor`).  After a
  warmup calibration it compares each step against ``base_median +
  (surface_prediction_now - surface_prediction_at_calibration)`` — the
  surface enters as a *delta*, so the watchdog needs no absolute model
  of the step (model compute dominates the wall; the surface only
  predicts how the memory term moves).  Sustained deviation beyond a
  hysteresis band raises a typed :class:`DriftEvent`; a cooldown and a
  re-arm band keep one incident from firing a stream of events.

* :class:`OnlineRecharacterizer` — on drift, a SMALL probe sweep at
  the live surface coordinates through the ordinary coordinator path
  (:func:`repro.core.characterize.refresh_surface_cells`) with the
  resilience stack engaged: faulted/noisy probes degrade or flag per
  ``core/exec/resilience`` and a failed sweep returns a flagged
  :class:`RefreshResult` instead of raising into the serving loop.
  On the spmd backend the sweep journals to a deterministic sidecar
  (:class:`repro.core.exec.SweepJournal`), so an engine restart
  *resumes* a half-done probe sweep value-identically; the sidecar is
  deleted after a successful merge so a LATER refresh at the same
  coordinates measures fresh instead of replaying stale values.

* :class:`MigrationGuard` — when the refreshed surface flips the
  advisor's KV-pool decision (via
  :meth:`repro.core.placement.PlacementAdvisor.readvise`), the actual
  migration is guarded twice: a minimum predicted gain + cool-down so
  placement cannot flap, and a post-migration verification window that
  ROLLS BACK if the observed step time regresses beyond
  ``regress_band`` of the pre-migration median.

:class:`ServeMonitor` composes the three into the single ``on_step``
hook the engine calls from its monitored decode loop.
"""
from __future__ import annotations

import logging
import os
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from repro.core.characterize import ONLINE_QUALIFIER, refresh_surface_cells
from repro.core.placement import (ContentionSpec, MemObject,
                                  PlacementAdvisor, kv_cache_object)
from repro.runtime.fault_tolerance import StragglerMonitor

log = logging.getLogger(__name__)

__all__ = ["ContentionWatchdog", "DriftEvent", "GuardConfig",
           "MigrationGuard", "MigrationRecord", "MonitorAction",
           "OnlineRecharacterizer", "RefreshResult", "ServeMonitor",
           "WatchdogConfig"]


# ---------------------------------------------------------------------------
# Watchdog
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class WatchdogConfig:
    """Hysteresis band for the drift detector.

    ``band`` — a step slower than ``band x`` expected (or faster than
    ``1/band x``) counts toward the deviation streak; ``rearm`` — the
    streak resets once steps come back inside ``[1/rearm, rearm]``;
    ``sustain`` — consecutive deviating steps before a
    :class:`DriftEvent` fires; ``warmup`` — steps used to calibrate
    the base median after a (re)base; ``cooldown`` — steps after an
    event before the next may fire."""
    band: float = 1.5
    rearm: float = 1.2
    sustain: int = 8
    warmup: int = 8
    window: int = 64
    cooldown: int = 64

    def __post_init__(self):
        if self.band <= 1.0 or self.rearm <= 1.0 or self.rearm > self.band:
            raise ValueError(
                f"need 1 < rearm <= band, got rearm={self.rearm} "
                f"band={self.band}")
        if self.sustain < 1 or self.warmup < 1:
            raise ValueError("sustain and warmup must be >= 1")


@dataclass(frozen=True)
class DriftEvent:
    """Sustained deviation of observed step time from the surface's
    expectation at the live coordinates."""
    step: int
    observed_ns: float
    expected_ns: float
    ratio: float
    pool: str
    coord: Dict[str, float] = field(default_factory=dict)

    def to_dict(self) -> Dict[str, Any]:
        return {"step": self.step, "observed_ns": self.observed_ns,
                "expected_ns": self.expected_ns, "ratio": self.ratio,
                "pool": self.pool, "coord": dict(self.coord)}


class ContentionWatchdog:
    """Deviation detector over the shared :class:`StragglerMonitor`.

    ``record(step, wall_ns, pred_ns)`` feeds one observed step plus
    the surface's current prediction of the memory term; the first
    ``warmup`` steps after a (re)base calibrate ``(base_median,
    base_pred)``, after which the expectation tracks the surface:
    ``expected = base_median + (pred - base_pred)``."""

    def __init__(self, cfg: Optional[WatchdogConfig] = None):
        self.cfg = cfg or WatchdogConfig()
        self.monitor = StragglerMonitor(window=self.cfg.window)
        self.base_median_ns: Optional[float] = None
        self.base_pred_ns: float = 0.0
        self._streak = 0
        self._cooldown_until = -1
        self.events: List[DriftEvent] = []

    def rebase(self) -> None:
        """Restart calibration — the regime legitimately changed
        (migration, rollback, new binding)."""
        self.monitor.reset()
        self.base_median_ns = None
        self._streak = 0

    def expected_ns(self, pred_ns: float) -> Optional[float]:
        if self.base_median_ns is None:
            return None
        return max(self.base_median_ns + (pred_ns - self.base_pred_ns),
                   1e-9)

    def record(self, step: int, wall_ns: float, pred_ns: float, *,
               pool: str = "", coord: Optional[Dict[str, float]] = None,
               ) -> Optional[DriftEvent]:
        cfg = self.cfg
        self.monitor.record(step, wall_ns)
        if self.base_median_ns is None:
            if len(self.monitor.times) >= cfg.warmup:
                self.base_median_ns = self.monitor.median()
                self.base_pred_ns = pred_ns
            return None
        expected = self.expected_ns(pred_ns)
        ratio = wall_ns / expected
        if ratio > cfg.band or ratio < 1.0 / cfg.band:
            self._streak += 1
        elif 1.0 / cfg.rearm <= ratio <= cfg.rearm:
            self._streak = 0
        if self._streak >= cfg.sustain and step >= self._cooldown_until:
            self._streak = 0
            self._cooldown_until = step + cfg.cooldown
            ev = DriftEvent(step, wall_ns, expected, ratio, pool,
                            dict(coord or {}))
            self.events.append(ev)
            return ev
        return None


# ---------------------------------------------------------------------------
# Background re-characterization
# ---------------------------------------------------------------------------


@dataclass
class RefreshResult:
    """One probe sweep's outcome.  ``failed=True`` + ``error`` instead
    of an exception — a broken probe path must never kill serving."""
    keys: List[Any] = field(default_factory=list)
    stats: Dict[str, Any] = field(default_factory=dict)
    failed: bool = False
    error: str = ""
    journal: str = ""


class OnlineRecharacterizer:
    """Runs :func:`refresh_surface_cells` at the live coordinates with
    the coordinator's resilience stack engaged, journaled, and with
    every failure downgraded to a flagged :class:`RefreshResult`.

    ``refresh`` is the injection seam for tests/benchmarks: it defaults
    to :func:`refresh_surface_cells` and receives the same kwargs."""

    def __init__(self, coord, db, *, pools: Optional[List[str]] = None,
                 stress_pools: Optional[List[str]] = None,
                 buffer_bytes: int = 64 << 10, iters: int = 50,
                 max_stressors: Optional[int] = None,
                 journal_dir: Optional[str] = None,
                 refresh=None):
        self.coord = coord
        self.db = db
        self.pools = pools
        self.stress_pools = stress_pools
        self.buffer_bytes = buffer_bytes
        self.iters = iters
        self.max_stressors = max_stressors
        self.journal_dir = journal_dir
        self.refresh = refresh or refresh_surface_cells

    def _journal_path(self, rw: float, ir: float) -> Optional[str]:
        """Deterministic per-coordinate sidecar — a restarted engine
        that drifts at the SAME coordinates resumes the same journal.
        Journaling needs the spmd backend (the journal records planned
        dispatch groups)."""
        if self.journal_dir is None or self.coord.backend != "spmd":
            return None
        os.makedirs(self.journal_dir, exist_ok=True)
        return os.path.join(self.journal_dir,
                            f"online-rw{rw:.4f}-ir{ir:.4f}.jsonl")

    def run(self, rw_ratio: float, inject_rate: float,
            drift: Optional[Dict[str, Any]] = None) -> RefreshResult:
        pools = self.pools if self.pools is not None \
            else self.db.observer_pools()
        journal = self._journal_path(rw_ratio, inject_rate)
        try:
            keys, stats = self.refresh(
                self.coord, self.db, pools=pools,
                stress_pools=self.stress_pools, rw_ratio=rw_ratio,
                inject_rate=inject_rate, buffer_bytes=self.buffer_bytes,
                iters=self.iters, max_stressors=self.max_stressors,
                drift=drift, journal=journal)
        except Exception as exc:        # noqa: BLE001 — flag, never raise
            log.warning("online probe sweep failed (%s); serving "
                        "continues on the stale surface", exc)
            return RefreshResult(failed=True, error=repr(exc),
                                 journal=journal or "")
        if journal and os.path.exists(journal):
            # the sidecar served its purpose: a LATER refresh at the
            # same coordinates must measure fresh, not replay this one
            os.unlink(journal)
        return RefreshResult(keys=list(keys), stats=dict(stats),
                             journal=journal or "")


# ---------------------------------------------------------------------------
# Migration guard
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class GuardConfig:
    """``min_gain_frac`` — re-advise hysteresis (the readvise floor);
    ``cooldown_steps`` — steps between guarded actions; ``verify_steps``
    — post-migration observation window; ``regress_band`` — roll back
    when the post-migration median exceeds this multiple of the
    pre-migration median."""
    min_gain_frac: float = 0.1
    cooldown_steps: int = 256
    verify_steps: int = 16
    regress_band: float = 1.1


@dataclass
class MigrationRecord:
    step: int
    from_pool: str
    to_pool: str
    predicted_gain_frac: float
    rolled_back: bool = False
    reason: str = ""

    def to_dict(self) -> Dict[str, Any]:
        return {"step": self.step, "from_pool": self.from_pool,
                "to_pool": self.to_pool,
                "predicted_gain_frac": self.predicted_gain_frac,
                "rolled_back": self.rolled_back, "reason": self.reason}


@dataclass
class MonitorAction:
    """What the engine must do to the live caches this step."""
    kind: str               # "migrate" | "rollback"
    to_pool: str
    record: MigrationRecord


class MigrationGuard:
    """Cool-down + post-migration verification with rollback."""

    def __init__(self, cfg: Optional[GuardConfig] = None):
        self.cfg = cfg or GuardConfig()
        self._last_action_step: Optional[int] = None
        self._active: Optional[Tuple[MigrationRecord, float,
                                     List[float]]] = None

    @property
    def verifying(self) -> bool:
        return self._active is not None

    def allows(self, step: int) -> bool:
        if self._active is not None:
            return False
        if self._last_action_step is None:
            return True
        return step - self._last_action_step >= self.cfg.cooldown_steps

    def begin(self, step: int, record: MigrationRecord,
              pre_median_ns: float) -> None:
        if not self.allows(step):
            raise RuntimeError("migration guard: begin() while "
                               "cooling down or verifying")
        self._last_action_step = step
        self._active = (record, float(pre_median_ns), [])

    def observe(self, step: int, wall_ns: float,
                ) -> Optional[MigrationRecord]:
        """Feed one post-migration step.  Returns the migration record
        (marked ``rolled_back``) when the verification window closed on
        a regression; ``None`` otherwise."""
        if self._active is None:
            return None
        record, pre_med, walls = self._active
        walls.append(float(wall_ns))
        if len(walls) < self.cfg.verify_steps:
            return None
        walls_sorted = sorted(walls)
        post_med = walls_sorted[len(walls_sorted) // 2]
        self._active = None
        self._last_action_step = step
        if post_med > self.cfg.regress_band * pre_med:
            record.rolled_back = True
            record.reason = (
                f"post-migration median {post_med:.0f}ns regressed "
                f"beyond {self.cfg.regress_band:.2f}x pre-migration "
                f"median {pre_med:.0f}ns")
            return record
        record.reason = (f"verified: post-migration median "
                         f"{post_med:.0f}ns vs pre {pre_med:.0f}ns")
        return None


# ---------------------------------------------------------------------------
# The composed monitor
# ---------------------------------------------------------------------------


class ServeMonitor:
    """The engine-facing composition: ``bind`` the live KV workload,
    then call :meth:`on_step` once per timed decode step; the returned
    :class:`MonitorAction` (if any) tells the engine to move its
    caches.  The advisor should carry
    ``qualifier=``:data:`~repro.core.characterize.ONLINE_QUALIFIER`
    so re-advice prefers refreshed cells (see :meth:`online_advisor`).
    """

    def __init__(self, advisor: PlacementAdvisor,
                 recharacterizer: Optional[OnlineRecharacterizer] = None,
                 *, watchdog: Optional[WatchdogConfig] = None,
                 guard: Optional[GuardConfig] = None,
                 capacities: Optional[Dict[str, int]] = None):
        self.advisor = advisor
        self.recharacterizer = recharacterizer
        self.watchdog = ContentionWatchdog(watchdog)
        self.guard = MigrationGuard(guard)
        self.capacities = capacities
        self.step = 0
        self.pool = ""
        self.drift_events: List[DriftEvent] = []
        self.migrations: List[MigrationRecord] = []
        self.refreshes: List[RefreshResult] = []
        self.held: List[Tuple[int, str]] = []
        self._obj: Optional[MemObject] = None
        self._contention: Optional[ContentionSpec] = None
        self._pred_ns: float = 0.0

    @staticmethod
    def online_advisor(db, platform, *, pools=None) -> PlacementAdvisor:
        """An advisor that resolves refreshed-online surfaces first."""
        return PlacementAdvisor(db, platform, pools=pools,
                                qualifier=ONLINE_QUALIFIER)

    # -- binding -------------------------------------------------------------
    def bind(self, *, kv_bytes: int, rw_mix: float, pool: str,
             inject_rate: Optional[float] = None,
             capacities: Optional[Dict[str, int]] = None) -> None:
        """(Re)bind the live KV workload.  Rebasing only happens when
        the binding actually changed, so repeated ``generate`` calls at
        the same shape keep the calibrated watchdog."""
        obj = kv_cache_object("kv", kv_bytes,
                              bytes_read_per_token=float(kv_bytes))
        contention = ContentionSpec(0, rw_ratio=float(rw_mix),
                                    inject_rate=inject_rate)
        if capacities is not None:
            self.capacities = capacities
        changed = (obj != self._obj or contention != self._contention
                   or pool != self.pool)
        self._obj = obj
        self._contention = contention
        self.pool = pool
        if changed:
            self.watchdog.rebase()
        self._refresh_prediction()

    def _refresh_prediction(self) -> None:
        try:
            self._pred_ns = self.advisor.predict_ns(
                self._obj, self.pool, self._contention)
        except KeyError:
            # no surface for the live pool at all: the watchdog still
            # works — the prediction delta is simply always zero
            self._pred_ns = 0.0

    def coord(self) -> Dict[str, float]:
        c = self._contention
        out: Dict[str, float] = {}
        if c is not None and c.rw_ratio is not None:
            out["rw_ratio"] = c.rw_ratio
        if c is not None and c.inject_rate is not None:
            out["inject_rate"] = c.inject_rate
        return out

    # -- the per-step hook ---------------------------------------------------
    def on_step(self, wall_ns: float) -> Optional[MonitorAction]:
        if self._obj is None:
            raise RuntimeError("ServeMonitor.on_step before bind()")
        self.step += 1
        step = self.step

        # 1. an active post-migration verification window sees the
        #    step FIRST — a regression rolls the caches back before the
        #    watchdog can re-interpret it as fresh drift
        rb = self.guard.observe(step, wall_ns)
        if rb is not None:
            self.pool = rb.from_pool
            self._refresh_prediction()
            self.watchdog.rebase()
            log.warning("migration rolled back: %s", rb.reason)
            return MonitorAction("rollback", rb.from_pool, rb)
        if self.guard.verifying:
            return None                  # verifying: watchdog holds off

        # 2. the watchdog
        ev = self.watchdog.record(step, wall_ns, self._pred_ns,
                                  pool=self.pool, coord=self.coord())
        if ev is None:
            return None
        self.drift_events.append(ev)
        log.warning("contention drift at step %d: observed %.0fns vs "
                    "expected %.0fns (%.2fx) on pool %r", step,
                    ev.observed_ns, ev.expected_ns, ev.ratio, self.pool)

        # 3. probe sweep at the live coordinates (resilient, journaled)
        if self.recharacterizer is None:
            return None
        c = self._contention
        res = self.recharacterizer.run(
            c.rw_ratio if c.rw_ratio is not None else 0.5,
            c.inject_rate if c.inject_rate is not None else 1.0,
            drift=ev.to_dict())
        self.refreshes.append(res)
        if res.failed:
            return None                  # flagged; serving continues

        # 4. re-advise against the refreshed surface, migrate if the
        #    guarded gain clears the hysteresis floor
        self._refresh_prediction()
        decision = self.advisor.readvise(
            [self._obj], c, {self._obj.name: self.pool},
            capacities=self.capacities,
            min_gain_frac=self.guard.cfg.min_gain_frac)
        move = decision.moves.get(self._obj.name)
        if move is None:
            reason = decision.held.get(
                self._obj.name, "re-advice kept the current pool")
            self.held.append((step, reason))
            return None
        if not self.guard.allows(step):
            self.held.append((step, "migration guard cooling down"))
            return None
        src, dst = move
        record = MigrationRecord(step, src, dst,
                                 decision.predicted_gain_frac)
        # the rollback baseline is the DRIFTED regime the migration is
        # escaping (the samples that formed the deviation streak), not
        # the full window — else a migration that improves on drift but
        # not on the old calm regime would falsely roll back
        recent = sorted(
            self.watchdog.monitor.times[-self.watchdog.cfg.sustain:])
        pre_med = recent[len(recent) // 2] if recent else wall_ns
        self.guard.begin(step, record, pre_med)
        self.migrations.append(record)
        self.pool = dst
        self._refresh_prediction()
        self.watchdog.rebase()
        log.warning("migrating KV cache %s -> %s (predicted gain "
                    "%.1f%%)", src, dst,
                    100.0 * decision.predicted_gain_frac)
        return MonitorAction("migrate", dst, record)
