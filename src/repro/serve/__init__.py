"""Serving: the placed-KV engine + the online drift-monitoring loop."""
from repro.serve.engine import (GenerateResult, ServeEngine, cache_bytes,
                                choose_kv_pool, decode_rw_mix,
                                pool_capacities)
from repro.serve.monitor import (ContentionWatchdog, DriftEvent,
                                 GuardConfig, MigrationGuard,
                                 MigrationRecord, MonitorAction,
                                 OnlineRecharacterizer, RefreshResult,
                                 ServeMonitor, WatchdogConfig)

__all__ = ["ContentionWatchdog", "DriftEvent", "GenerateResult",
           "GuardConfig", "MigrationGuard", "MigrationRecord",
           "MonitorAction", "OnlineRecharacterizer", "RefreshResult",
           "ServeEngine", "ServeMonitor", "WatchdogConfig",
           "cache_bytes", "choose_kv_pool", "decode_rw_mix",
           "pool_capacities"]
