"""Microbatched train step: chunked CE, remat, ZeRO-1, grad compression.

The step is one jit'd program:

  scan over microbatches                 (bounded activation residency)
    -> lm.forward (period-scanned layers, optional per-period remat)
    -> chunked cross-entropy             (no (B,S,V) logits tensor)
    -> f32 gradient accumulation
  -> optional int8-EF gradient compression
  -> global-norm clip + AdamW            (f32 moments, ZeRO-1 sharded)

Sharding is declarative: params/opt PartitionSpecs come from
``ShardingRules``; activation constraints are applied inside the model via
the ``constrain`` callback.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec, TrainConfig
from repro.models import lm
from repro.optim import adamw
from repro.parallel import compression
from repro.parallel.sharding import ShardingRules, constrain

TrainState = Dict[str, Any]     # {"params", "opt", ["err"]}


# ---------------------------------------------------------------------------
# Sharding helpers
# ---------------------------------------------------------------------------


def make_constrain(rules: ShardingRules) -> Callable:
    mesh = rules.mesh

    def cst(v, name: str):
        if name == "hidden":
            return constrain(v, mesh, rules.hidden_spec())
        if name == "ffn":
            return constrain(v, mesh, rules.ffn_spec())
        if name == "kv":
            return constrain(v, mesh, rules.kv_spec())
        if name == "dispatch":
            return constrain(v, mesh, rules.dispatch_spec())
        if name == "logits":
            return constrain(v, mesh, rules.logits_spec())
        if name == "blocked_q":
            return constrain(v, mesh, rules.blocked_q_spec(v.shape[1]))
        if name == "blocked_kv":
            return constrain(v, mesh, rules.blocked_kv_spec(v.shape[1]))
        if name == "q_seq":
            return constrain(v, mesh, rules.q_seq_spec())
        if name == "kv_rep":
            return constrain(v, mesh, rules.kv_rep_spec())
        return v

    return cst


def state_specs(cfg: ModelConfig, rules: ShardingRules,
                tcfg: TrainConfig, params_struct) -> TrainState:
    pspecs = lm.param_specs(rules, params_struct)
    ospecs = adamw.opt_specs(pspecs, params_struct, rules.mesh,
                             zero1=tcfg.zero1)
    specs: TrainState = {"params": pspecs, "opt": ospecs}
    if tcfg.grad_compression == "int8_ef":
        specs["err"] = jax.tree.map(lambda s: s, pspecs)
    return specs


def batch_specs(rules: ShardingRules, cfg: ModelConfig):
    b = rules.batch if rules.batch else None
    toks = P(b, None)
    fe = None
    if cfg.frontend == "audio":
        fe = {"frame_embeds": P(b, None, None)}
    elif cfg.frontend == "vlm":
        fe = {"prefix_embeds": P(b, None, None)}
    return toks, toks, fe


# ---------------------------------------------------------------------------
# Chunked cross-entropy
# ---------------------------------------------------------------------------


def chunked_ce(params, hidden, labels, *, cfg: ModelConfig, chunk: int,
               cst) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """-> (sum of token losses, token count).  labels < 0 are masked."""
    b, s, d = hidden.shape
    chunk = min(chunk, s)
    n = s // chunk
    rem = s - n * chunk
    w = params["embed"] if cfg.tie_embeddings else params["unembed"]

    def chunk_loss(h, y):
        logits = jnp.einsum("bcd,vd->bcv", h, w,
                            preferred_element_type=jnp.float32)
        logits = cst(logits, "logits")
        lse = jax.nn.logsumexp(logits, axis=-1)
        # label logit via iota-mask reduction, NOT take_along_axis: a
        # gather over the vocab-sharded axis forces GSPMD to all-gather
        # the logits chunk; the masked sum keeps the vocab dim sharded
        # and reduces with a (B, C)-sized all-reduce instead.
        vocab_ids = jax.lax.broadcasted_iota(
            jnp.int32, logits.shape, dimension=2)
        onehot = vocab_ids == jnp.maximum(y, 0)[..., None]
        ll = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
        valid = (y >= 0)
        loss = jnp.where(valid, lse - ll, 0.0)
        return jnp.sum(loss), jnp.sum(valid.astype(jnp.float32))

    if n:
        hc = jnp.moveaxis(
            hidden[:, :n * chunk].reshape(b, n, chunk, d), 1, 0)
        yc = jnp.moveaxis(
            labels[:, :n * chunk].reshape(b, n, chunk), 1, 0)

        def body(carry, xs):
            ls, cnt = chunk_loss(*xs)
            return (carry[0] + ls, carry[1] + cnt), None

        (loss_sum, count), _ = jax.lax.scan(
            body, (jnp.float32(0), jnp.float32(0)), (hc, yc))
    else:
        loss_sum = jnp.float32(0)
        count = jnp.float32(0)
    if rem:
        ls, cnt = chunk_loss(hidden[:, n * chunk:], labels[:, n * chunk:])
        loss_sum, count = loss_sum + ls, count + cnt
    return loss_sum, count


# ---------------------------------------------------------------------------
# The train step
# ---------------------------------------------------------------------------


def make_loss_fn(cfg: ModelConfig, rules: ShardingRules, tcfg: TrainConfig):
    cst = make_constrain(rules)

    def loss_fn(params, tokens, labels, frontend):
        hidden, _, aux = lm.forward(
            params, tokens, cfg=cfg, mode="train", frontend=frontend,
            constrain=cst, remat=tcfg.remat)
        loss_sum, count = chunked_ce(params, hidden, labels, cfg=cfg,
                                     chunk=tcfg.loss_chunk, cst=cst)
        loss = loss_sum / jnp.maximum(count, 1.0)
        metrics = {"ce_loss": loss, "tokens": count}
        if "moe_aux_loss" in aux:
            loss = loss + aux["moe_aux_loss"] + aux["moe_z_loss"]
            metrics.update(
                moe_aux=aux["moe_aux_loss"],
                moe_drop_frac=aux["moe_drop_frac"])
        metrics["loss"] = loss
        return loss, metrics

    return loss_fn


def make_train_step(cfg: ModelConfig, rules: ShardingRules,
                    tcfg: TrainConfig, *, microbatches: int = 1):
    loss_fn = make_loss_fn(cfg, rules, tcfg)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)
    lr_fn = adamw.warmup_cosine(tcfg)

    def step(state: TrainState, tokens, labels, frontend=None
             ) -> Tuple[TrainState, Dict[str, jnp.ndarray]]:
        params = state["params"]
        b = tokens.shape[0]
        mb = microbatches
        assert b % mb == 0, (b, mb)

        if mb == 1:
            (loss, metrics), grads = grad_fn(params, tokens, labels,
                                             frontend)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        else:
            def slice_mb(x, i):
                # interleaved layout (row r -> microbatch r % mb): keeps the
                # sharded batch dim intact, so slicing is local to every
                # device (a contiguous block split would need an all-to-all)
                return x.reshape(b // mb, mb, *x.shape[1:])[:, i]

            def body(carry, i):
                g_acc, l_acc = carry
                fe = None if frontend is None else jax.tree.map(
                    lambda x: slice_mb(x, i), frontend)
                (loss, metrics), g = grad_fn(
                    params, slice_mb(tokens, i), slice_mb(labels, i), fe)
                g_acc = jax.tree.map(
                    lambda a, x: a + x.astype(jnp.float32), g_acc, g)
                return (g_acc, l_acc + loss), metrics

            g_zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (g_sum, loss_sum), metrics = jax.lax.scan(
                body, (g_zero, jnp.float32(0)),
                jnp.arange(mb, dtype=jnp.int32))
            grads = jax.tree.map(lambda g: g / mb, g_sum)
            metrics = jax.tree.map(lambda m: m[-1], metrics)
            metrics["loss"] = loss_sum / mb

        new_state: TrainState = {}
        if tcfg.grad_compression == "int8_ef":
            grads, new_err = compression.compress_decompress(
                grads, state["err"])
            new_state["err"] = new_err

        new_params, new_opt, stats = adamw.adamw_update(
            params, grads, state["opt"], tcfg, lr_fn)
        new_state["params"] = new_params
        new_state["opt"] = new_opt
        metrics.update(stats)
        return new_state, metrics

    return step


def init_state(cfg: ModelConfig, tcfg: TrainConfig, key) -> TrainState:
    params = lm.init_params(cfg, key)
    state: TrainState = {"params": params,
                         "opt": adamw.init_opt_state(params)}
    if tcfg.grad_compression == "int8_ef":
        state["err"] = compression.init_error_state(params)
    return state


def state_struct(cfg: ModelConfig, tcfg: TrainConfig) -> TrainState:
    """Abstract TrainState (no allocation) for AOT lowering."""
    params_struct = jax.eval_shape(
        lambda: lm.init_params(cfg, jax.random.PRNGKey(0)))
    struct: TrainState = {
        "params": params_struct,
        "opt": adamw.opt_state_struct(params_struct)}
    if tcfg.grad_compression == "int8_ef":
        struct["err"] = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32),
            params_struct)
    return struct
