"""Token data pipeline: synthetic + memmap sources, shard-aware, prefetch.

Sources
-------
``SyntheticSource``  deterministic tokens from a seeded PRNG — every DP
                     shard draws a disjoint stream (seed mixes the shard
                     index), so global batches are reproducible at any
                     device count (elastic restarts keep the data order).
``MemmapSource``     flat binary token file (np.memmap, uint16/uint32),
                     sliced per shard by (step, shard) with wraparound.

``DataLoader`` assembles global (tokens, labels) batches, places them with
the batch sharding, synthesizes frontend-stub inputs (audio frames / VLM
patch embeddings) when the architecture needs them, and prefetches one
batch ahead on a background thread.
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Any, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, ShapeSpec


class SyntheticSource:
    """tokens[step] is a pure function of (seed, step) — restart-safe."""

    def __init__(self, vocab_size: int, seed: int = 0):
        self.vocab = vocab_size
        self.seed = seed

    def batch(self, step: int, batch: int, seq_len: int) -> np.ndarray:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        return rng.integers(0, self.vocab, (batch, seq_len + 1),
                            dtype=np.int32)


class MemmapSource:
    def __init__(self, path: str, vocab_size: int, dtype=np.uint16):
        self.tokens = np.memmap(path, dtype=dtype, mode="r")
        self.vocab = vocab_size

    def batch(self, step: int, batch: int, seq_len: int) -> np.ndarray:
        n = len(self.tokens)
        span = seq_len + 1
        out = np.empty((batch, span), np.int32)
        for b in range(batch):
            start = ((step * batch + b) * span) % max(n - span, 1)
            out[b] = self.tokens[start:start + span].astype(np.int32)
        return np.minimum(out, self.vocab - 1)


@dataclass
class Batch:
    tokens: Any
    labels: Any
    frontend: Optional[Dict[str, Any]] = None


class DataLoader:
    def __init__(self, cfg: ModelConfig, shape: ShapeSpec, *,
                 source=None, mesh=None, batch_sharding=None,
                 seed: int = 0, prefetch: int = 2):
        self.cfg = cfg
        self.shape = shape
        self.source = source or SyntheticSource(cfg.vocab_size, seed)
        self.mesh = mesh
        self.batch_sharding = batch_sharding
        self.seed = seed
        self.prefetch = prefetch

    # -- one host-side batch -------------------------------------------------
    def host_batch(self, step: int) -> Batch:
        b, s = self.shape.global_batch, self.shape.seq_len
        raw = self.source.batch(step, b, s)
        tokens, labels = raw[:, :-1], raw[:, 1:].copy()
        frontend = None
        if self.cfg.frontend == "audio":
            rng = np.random.default_rng(self.seed + 7919 + step)
            frontend = {"frame_embeds": rng.standard_normal(
                (b, s, self.cfg.d_model)).astype(np.float32) * 0.02}
        elif self.cfg.frontend == "vlm":
            rng = np.random.default_rng(self.seed + 104729 + step)
            p = self.cfg.n_prefix_embeds
            frontend = {"prefix_embeds": rng.standard_normal(
                (b, p, self.cfg.d_model)).astype(np.float32) * 0.02}
            labels[:, :p] = -1          # no loss on image positions
        return Batch(tokens, labels, frontend)

    def device_batch(self, step: int) -> Batch:
        hb = self.host_batch(step)
        put = (lambda x: jax.device_put(x, self.batch_sharding)) \
            if self.batch_sharding is not None else jnp.asarray
        fe = None
        if hb.frontend is not None:
            fe = {k: put(v) for k, v in hb.frontend.items()}
        return Batch(put(hb.tokens), put(hb.labels), fe)

    # -- prefetching iterator ---------------------------------------------
    def __iter__(self) -> Iterator[Batch]:
        q: "queue.Queue" = queue.Queue(maxsize=self.prefetch)
        stop = threading.Event()

        def producer():
            step = 0
            while not stop.is_set():
                try:
                    q.put(self.device_batch(step), timeout=0.5)
                    step += 1
                except queue.Full:
                    continue

        t = threading.Thread(target=producer, daemon=True)
        t.start()
        try:
            while True:
                yield q.get()
        finally:
            stop.set()
