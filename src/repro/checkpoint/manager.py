"""Mesh-agnostic checkpointing: manifest + per-leaf arrays, atomic, async.

Fault-tolerance contract (DESIGN.md §6):

* **mesh-agnostic**: the manifest records only *global* shapes/dtypes and
  the pytree structure; leaves are stored as full (gathered) arrays, so a
  checkpoint written on a 256-chip mesh restores onto 8 chips or 512 —
  the elastic-rescale path.
* **atomic**: writes go to ``step_<n>.tmp/`` and are renamed into place
  only after every leaf + manifest is fsynced — a killed job can never
  leave a half-checkpoint that restore would pick up.
* **async**: ``save_async`` snapshots device arrays to host, then writes
  on a background thread — the train loop blocks only for the
  device->host copy, not the filesystem.
* **keep-N GC** with the newest checkpoints retained.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

MANIFEST = "manifest.json"


def _flatten(tree) -> Tuple[List[Tuple[str, Any]], Any]:
    flat, tdef = jax.tree_util.tree_flatten_with_path(tree)
    items = []
    for kp, leaf in flat:
        path = "/".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in kp)
        items.append((path, leaf))
    return items, tdef


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # -- paths ---------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                if os.path.exists(os.path.join(self.dir, name, MANIFEST)):
                    out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        s = self.steps()
        return s[-1] if s else None

    # -- save ------------------------------------------------------------------
    def save(self, state, step: int) -> None:
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
        self._write(host, step)

    def save_async(self, state, step: int) -> None:
        self.wait()                       # one in flight at a time
        host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)

        def work():
            try:
                self._write(host, step)
            except BaseException as e:    # noqa: BLE001
                self._error = e

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _write(self, host_state, step: int) -> None:
        final = self._step_dir(step)
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        items, _ = _flatten(host_state)
        manifest = {"step": step, "leaves": {}}
        for path, leaf in items:
            fname = path.replace("/", ".") + ".npy"
            arr = np.asarray(leaf)
            with open(os.path.join(tmp, fname), "wb") as f:
                np.save(f, arr)
                f.flush()
                os.fsync(f.fileno())
            manifest["leaves"][path] = {
                "file": fname, "shape": list(arr.shape),
                "dtype": str(arr.dtype)}
        with open(os.path.join(tmp, MANIFEST), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)             # the atomic commit point
        self._gc()

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # -- restore -----------------------------------------------------------------
    def restore(self, target, step: Optional[int] = None,
                shardings=None):
        """Restore into the structure of `target` (pytree of arrays or
        ShapeDtypeStructs).  `shardings`: optional matching pytree of
        NamedShardings — this is where elastic re-meshing happens: the
        same checkpoint lands on whatever mesh the shardings describe."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self._step_dir(step)
        with open(os.path.join(d, MANIFEST)) as f:
            manifest = json.load(f)

        items, tdef = _flatten(target)
        shard_items = None
        if shardings is not None:
            shard_items, _ = _flatten(shardings)
        leaves = []
        for i, (path, tgt) in enumerate(items):
            meta = manifest["leaves"].get(path)
            if meta is None:
                raise KeyError(
                    f"checkpoint step {step} missing leaf {path!r}")
            arr = np.load(os.path.join(d, meta["file"]))
            if list(arr.shape) != list(tgt.shape):
                raise ValueError(
                    f"leaf {path}: checkpoint shape {arr.shape} != "
                    f"target {tgt.shape}")
            if shard_items is not None:
                arr = jax.device_put(arr, shard_items[i][1])
            else:
                arr = jax.device_put(arr.astype(tgt.dtype))
            leaves.append(arr)
        return jax.tree_util.tree_unflatten(tdef, leaves)
