from repro.parallel.sharding import (  # noqa: F401
    ShardingRules, make_rules, batch_axes, logical_to_spec, constrain,
)
