"""int8 error-feedback gradient compression + compressed ring all-reduce.

Distributed-optimization trick for bandwidth-bound gradient exchange:
gradients are quantized to int8 with a per-leaf f32 scale before crossing
the data-parallel axis; the quantization error is *carried* (error
feedback) so the scheme stays unbiased over time (1-bit-Adam-style, at
8 bits).

Two integration points:

* :func:`ef_quantize` / :func:`ef_dequantize` — the quantizer with error
  state, usable around any reduction.
* :func:`compressed_psum` — an explicit shard_map collective: int8
  payloads are summed as int32 across the axis (4x less ICI traffic than
  f32 psum), then rescaled.  Used by the train step when
  ``grad_compression="int8_ef"``.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

Params = Any
INT8_MAX = 127.0


def init_error_state(params: Params) -> Params:
    return jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _q_leaf(g: jnp.ndarray, err: jnp.ndarray):
    gf = g.astype(jnp.float32) + err
    scale = jnp.max(jnp.abs(gf)) / INT8_MAX + 1e-12
    q = jnp.clip(jnp.round(gf / scale), -INT8_MAX, INT8_MAX
                 ).astype(jnp.int8)
    new_err = gf - q.astype(jnp.float32) * scale
    return q, scale, new_err


def ef_quantize(grads: Params, err: Params
                ) -> Tuple[Params, Params, Params]:
    """-> (int8 grads, f32 scales, new error state)."""
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err)
    out = [_q_leaf(g, e) for g, e in zip(flat_g, flat_e)]
    q = jax.tree.unflatten(tdef, [o[0] for o in out])
    s = jax.tree.unflatten(tdef, [o[1] for o in out])
    ne = jax.tree.unflatten(tdef, [o[2] for o in out])
    return q, s, ne


def ef_dequantize(q: Params, scales: Params) -> Params:
    return jax.tree.map(
        lambda qq, ss: qq.astype(jnp.float32) * ss, q, scales)


def compress_decompress(grads: Params, err: Params
                        ) -> Tuple[Params, Params]:
    """Quantize+dequantize with error feedback (models the compressed
    exchange when the reduction itself is GSPMD-implicit)."""
    q, s, new_err = ef_quantize(grads, err)
    return ef_dequantize(q, s), new_err


# ---------------------------------------------------------------------------
# Explicit compressed all-reduce over a named axis (use under shard_map)
# ---------------------------------------------------------------------------


def compressed_psum(grads: Params, err: Params, axis: str
                    ) -> Tuple[Params, Params]:
    """All-reduce int8 payloads over `axis` (called inside shard_map).

    Each participant contributes an int8 tensor + f32 scale; the int8s are
    summed exactly in int32 (no overflow for axis sizes < 2^24/127), the
    scales are averaged... payloads cross the wire at 1/4 the bytes.
    Returns (mean gradient, new error state).
    """
    n = jax.lax.psum(1, axis)

    # Summing int8 then rescaling is only consistent when all ranks share
    # one scale, so we pmax the scale first (scalar — negligible traffic)
    # and quantize every rank against it.
    def reduce_exact(g, e):
        gf = g.astype(jnp.float32) + e
        smax = jax.lax.pmax(jnp.max(jnp.abs(gf)), axis) / INT8_MAX + 1e-12
        qq = jnp.clip(jnp.round(gf / smax), -INT8_MAX, INT8_MAX)
        new_e = gf - qq * smax
        total = jax.lax.psum(qq.astype(jnp.int32), axis)
        return total.astype(jnp.float32) * smax / n, new_e

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err)
    out = [reduce_exact(g, e) for g, e in zip(flat_g, flat_e)]
    mean = jax.tree.unflatten(tdef, [o[0] for o in out])
    ne = jax.tree.unflatten(tdef, [o[1] for o in out])
    return mean, ne
