"""Sharding rules: logical axis names -> mesh PartitionSpecs.

Parallelism map (see DESIGN.md §6):
  * batch        -> ("pod", "data") (whatever exists and divides)
  * TP (model)   -> d_ff, vocab, attention heads (when divisible), experts,
                    SSM heads
  * SP           -> KV sequence over "model" for archs whose KV head count
                    does not divide the model axis; KV-cache sequence over
                    ("data","model") for the batch=1 long-context shape
  * ZeRO-1       -> optimizer state additionally over "data"

All rules degrade to replication when a dimension does not divide the mesh
axis, so reduced CPU configs (1 device) use the same code path.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


def mesh_axis_size(mesh: Mesh, name: str) -> int:
    return dict(zip(mesh.axis_names, mesh.devices.shape)).get(name, 1)


def batch_axes(mesh: Mesh) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _axes_size(mesh: Mesh, axes: Sequence[str]) -> int:
    n = 1
    for a in axes:
        n *= mesh_axis_size(mesh, a)
    return n


def _maybe(axis, dim_size: int, mesh: Mesh):
    """Return `axis` if dim_size divides the axis size, else None."""
    if axis is None:
        return None
    axes = (axis,) if isinstance(axis, str) else tuple(axis)
    sz = _axes_size(mesh, axes)
    if sz > 1 and dim_size % sz == 0:
        return axis
    return None


@dataclass(frozen=True)
class ShardingRules:
    """Resolved sharding decisions for one (arch, mesh, shape-kind)."""
    mesh: Mesh
    cfg: ModelConfig
    batch: Tuple[str, ...]          # axes for the batch dim
    tp: str = "model"               # tensor-parallel axis
    attn_mode: str = "seq"          # "head" (KV heads TP) | "seq" (KV seq SP)
    kv_seq_axes: Tuple[str, ...] = ()   # axes sharding the KV-cache seq dim
    # seq mode with Q heads divisible by tp: shard wq/wo (and q
    # activations) over Q heads even though KV heads cannot shard —
    # Megatron column/row attention with replicated (small, GQA) KV.
    # Removes the replicated-attention-weight f32 grad buffers.
    q_heads_tp: bool = False

    # ---- parameter specs -------------------------------------------------
    def param_spec(self, path: str, shape: Tuple[int, ...]) -> P:
        """Spec for a parameter leaf. `path` is a '/'-joined name."""
        m, mesh = self.tp, self.mesh
        leaf = path.split("/")[-1]
        if leaf in ("embed", "unembed"):
            return P(_maybe(m, shape[0], mesh), None)    # (vocab, d_model)
        if leaf in ("wq", "wo"):
            # (D, H, hd) / (H, hd, D): shard flattened head dim when possible
            h_dim = 1 if leaf == "wq" else 0
            return self._head_spec(shape, h_dim)
        if leaf in ("wk", "wv"):
            return self._head_spec(shape, 1)
        if leaf in ("w_in", "w_gate"):
            return P(None, _maybe(m, shape[-1], mesh))   # (D, F)
        if leaf == "w_out":
            return P(_maybe(m, shape[0], mesh), None)    # (F, D)
        if leaf == "router":
            return P(None, None)
        if leaf in ("we_in", "we_gate"):                 # (E, D, F)
            return P(_maybe(m, shape[0], mesh), None, None)
        if leaf == "we_out":                             # (E, F, D)
            return P(_maybe(m, shape[0], mesh), None, None)
        if leaf == "w_zxbcdt":                           # (D, zxbcdt)
            return P(None, _maybe(m, shape[-1], mesh))
        if leaf == "w_ssm_out":                          # (d_inner, D)
            return P(_maybe(m, shape[0], mesh), None)
        if leaf == "conv_w":                             # (K, channels)
            return P(None, _maybe(m, shape[-1], mesh))
        if leaf in ("A_log", "dt_bias", "ssm_D"):        # (H,)
            return P(_maybe(m, shape[0], mesh))
        if leaf == "ssm_norm":                           # (d_inner,)
            return P(_maybe(m, shape[0], mesh))
        # norms, biases, small vectors: replicate
        return P(*([None] * len(shape)))

    def _head_spec(self, shape: Tuple[int, ...], h_dim: int) -> P:
        mesh = self.mesh
        spec = [None] * len(shape)
        if self.attn_mode == "head":
            spec[h_dim] = _maybe(self.tp, shape[h_dim], mesh)
        elif self.q_heads_tp and shape[h_dim] == self.cfg.n_heads:
            # wq/wo only (their head dim is n_heads; wk/wv have n_kv_heads
            # which does not divide tp in this mode)
            spec[h_dim] = _maybe(self.tp, shape[h_dim], mesh)
        return P(*spec)

    # ---- activation specs ------------------------------------------------
    def hidden_spec(self) -> P:
        """(B, S, D) residual-stream activations."""
        return P(self.batch if self.batch else None, None, None)

    def ffn_spec(self) -> P:
        """(B, S, F) intermediate activations (TP over F)."""
        return P(self.batch if self.batch else None, None, self.tp)

    def q_spec(self) -> P:
        """(B, S, H, hd)."""
        h = self.tp if self.attn_mode == "head" else None
        return P(self.batch if self.batch else None, None, h, None)

    def kv_spec(self) -> P:
        """(B, S, KV, hd) — sequence-sharded in "seq" mode."""
        if self.attn_mode == "head":
            return P(self.batch if self.batch else None, None, self.tp, None)
        s = self.kv_seq_axes if self.kv_seq_axes else None
        return P(self.batch if self.batch else None, s, None, None)

    def kv_cache_spec(self) -> P:
        """(B, S, KV, hd) persistent cache."""
        return self.kv_spec()

    def logits_spec(self) -> P:
        """(B, S, V) — vocab TP."""
        return P(self.batch if self.batch else None, None, self.tp)

    # ---- attention train-path specs (blocked / one-shot, see
    # models/attention.py) ------------------------------------------------
    def blocked_q_spec(self, nb: int) -> P:
        """(B, nb, block, KV, G, hd): blocks over tp when they divide."""
        if self.attn_mode == "head":
            kv = self.cfg.n_kv_heads
            return P(self.batch if self.batch else None, None, None,
                     _maybe(self.tp, kv, self.mesh), None, None)
        return P(self.batch if self.batch else None,
                 _maybe(self.tp, nb, self.mesh), None, None, None, None)

    def blocked_kv_spec(self, nb: int) -> P:
        """(B, nb, ext, KV, hd)."""
        if self.attn_mode == "head":
            kv = self.cfg.n_kv_heads
            return P(self.batch if self.batch else None, None, None,
                     _maybe(self.tp, kv, self.mesh), None)
        return P(self.batch if self.batch else None,
                 _maybe(self.tp, nb, self.mesh), None, None, None)

    def q_seq_spec(self) -> P:
        """(B, S, H, hd) q activations for the one-shot train path:
        heads-TP when possible (fully local attention, Megatron-style),
        else sequence-sharded."""
        if self.attn_mode == "head" or self.q_heads_tp:
            return P(self.batch if self.batch else None, None, self.tp,
                     None)
        return P(self.batch if self.batch else None, self.tp, None, None)

    def kv_rep_spec(self) -> P:
        """(B, S, KV, hd) replicated over tp (gathered once per layer)."""
        if self.attn_mode == "head":
            return P(self.batch if self.batch else None, None, self.tp,
                     None)
        return P(self.batch if self.batch else None, None, None, None)

    def ssm_state_spec(self) -> P:
        """(B, H, P, N) recurrent state — heads TP."""
        h = _maybe(self.tp, self.cfg.ssm.n_heads(self.cfg.d_model),
                   self.mesh) if self.cfg.ssm else self.tp
        return P(self.batch if self.batch else None, h, None, None)

    def dispatch_spec(self) -> P:
        """(G, E, cap, D) MoE dispatch buffer — experts TP."""
        e = self.cfg.moe.n_experts if self.cfg.moe else 0
        return P(self.batch if self.batch else None,
                 _maybe(self.tp, e, self.mesh), None, None)


def make_rules(cfg: ModelConfig, mesh: Mesh, *, global_batch: int,
               shape_kind: str = "train") -> ShardingRules:
    b_axes = []
    remaining = global_batch
    for a in batch_axes(mesh):
        sz = mesh_axis_size(mesh, a)
        if remaining % sz == 0 and remaining >= sz:
            b_axes.append(a)
            remaining //= sz
    tp_size = mesh_axis_size(mesh, "model")
    if cfg.n_kv_heads and tp_size > 1 and cfg.n_kv_heads % tp_size == 0:
        attn_mode = "head"
        kv_seq: Tuple[str, ...] = ()
        q_heads_tp = False
    else:
        attn_mode = "seq"
        kv_seq = ("model",) if tp_size > 1 else ()
        # batch=1 long-context: spread the KV sequence over spare batch axes
        unused = tuple(a for a in batch_axes(mesh) if a not in b_axes)
        kv_seq = unused + kv_seq
        q_heads_tp = bool(cfg.n_heads and tp_size > 1
                          and cfg.n_heads % tp_size == 0)
    return ShardingRules(mesh=mesh, cfg=cfg, batch=tuple(b_axes),
                         attn_mode=attn_mode, kv_seq_axes=kv_seq,
                         q_heads_tp=q_heads_tp)


def logical_to_spec(rules: ShardingRules, tree, path_prefix: str = ""):
    """Map a param pytree to a pytree of PartitionSpecs by leaf path."""
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    specs = {}
    for kp, leaf in flat:
        path = "/".join(_key_str(k) for k in kp)
        specs[path] = rules.param_spec(path, leaf.shape)
    # rebuild tree
    def _build(kp, leaf):
        path = "/".join(_key_str(k) for k in kp)
        return specs[path]
    return jax.tree_util.tree_map_with_path(_build, tree)


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return str(k.idx)
    return str(k)


def constrain(x, mesh: Mesh, spec: P):
    """with_sharding_constraint that is a no-op off-mesh / on 1 device."""
    if mesh is None or mesh.size == 1 or isinstance(
            mesh, jax.sharding.AbstractMesh) and False:
        return x
    try:
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
    except ValueError:
        return x
