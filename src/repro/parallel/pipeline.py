"""GPipe-style pipeline parallelism over a "stage" mesh axis.

The assignment's fixed production mesh is (data, model) — PP is not part
of the 40-cell baseline — but a 1000-node deployment wants a stage axis
for cross-pod scaling, so the machinery is here as a first-class,
tested feature.

Mapping (DESIGN.md §6): one stage per mesh slice along ``stage``; the
schedule is plain GPipe — microbatches march left to right, activations
hop stages via ``jax.lax.ppermute`` (TPU-native neighbour exchange on the
ICI torus), and the whole schedule is a single ``lax.scan`` of
``n_micro + n_stages - 1`` ticks inside one ``shard_map``.  Bubble
fraction is the textbook (S-1)/(T+S-1); pick n_micro >> n_stages.

``apply_stage(stage_params, x)`` is user code (e.g. a slab of decoder
layers); it must be shape-preserving, which all our decoder stacks are.
"""
from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from repro import compat


def gpipe_schedule(apply_stage: Callable[[Any, jnp.ndarray], jnp.ndarray],
                   *, n_stages: int, n_micro: int, axis: str = "stage"):
    """Returns per_device(params_stage, x_micro) -> y_micro to be run
    under shard_map over the ``axis`` mesh dimension.

    params_stage: this stage's parameters (already sharded by stage).
    x_micro: (n_micro, mb, ...) — meaningful on stage 0 only.
    Returns (n_micro, mb, ...) — meaningful on the last stage only.
    """
    if n_micro < 1 or n_stages < 1:
        raise ValueError((n_micro, n_stages))
    ticks = n_micro + n_stages - 1
    perm = [(i, i + 1) for i in range(n_stages - 1)]

    def per_device(params_stage, x_micro):
        stage = jax.lax.axis_index(axis)
        # params arrive stacked (n_stages, ...); this shard holds 1 stage
        params_stage = jax.tree.map(lambda a: a[0], params_stage)
        mb_shape = x_micro.shape[1:]
        out0 = jnp.zeros_like(x_micro)

        def tick(carry, t):
            act, out = carry
            # 1) receive the neighbour's activation (stage s gets s-1's)
            act_in = jax.lax.ppermute(act, axis, perm) if perm else act
            # 2) stage 0 injects microbatch t instead
            feed = jax.lax.dynamic_index_in_dim(
                x_micro, jnp.minimum(t, n_micro - 1), 0, keepdims=False)
            act_in = jnp.where(stage == 0, feed, act_in)
            # 3) compute when this stage has live data: s <= t < s + n_micro
            live = (t >= stage) & (t < stage + n_micro)
            y = apply_stage(params_stage, act_in)
            act_out = jnp.where(live, y, act_in)
            # 4) the last stage banks finished microbatch t - (S-1)
            mb_idx = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            bank = live & (stage == n_stages - 1)
            upd = jnp.where(
                bank, act_out,
                jax.lax.dynamic_index_in_dim(out, mb_idx, 0, False))
            out = jax.lax.dynamic_update_index_in_dim(out, upd, mb_idx, 0)
            return (act_out, out), None

        act0 = jnp.zeros(mb_shape, x_micro.dtype)
        # the carry becomes device-varying after ppermute: mark it so
        act0, out0 = compat.pvary((act0, out0), (axis,))
        (_, out), _ = jax.lax.scan(
            tick, (act0, out0), jnp.arange(ticks, dtype=jnp.int32))
        # only the last stage banked anything (zeros elsewhere): reduce to
        # make the result replicated across stages
        return jax.lax.psum(out, axis)

    return per_device


def make_gpipe(mesh: Mesh, apply_stage, *, n_micro: int,
               axis: str = "stage",
               x_spec: P = P(None), params_spec: P = None):
    """shard_map-wrapped GPipe runner on ``mesh`` (must carry ``axis``)."""
    if params_spec is None:
        params_spec = P(axis)
    n_stages = dict(zip(mesh.axis_names, mesh.devices.shape))[axis]
    per_device = gpipe_schedule(apply_stage, n_stages=n_stages,
                                n_micro=n_micro, axis=axis)
    return compat.shard_map(per_device, mesh=mesh,
                            in_specs=(params_spec, x_spec),
                            out_specs=x_spec)


def reference_pipeline(apply_stage, params_all, x_micro):
    """Oracle: run every stage sequentially on one device.

    params_all: (n_stages, ...) stacked stage params; x_micro (n_micro, ...).
    """
    n_stages = jax.tree.leaves(params_all)[0].shape[0]

    def run_micro(x):
        for s in range(n_stages):
            p = jax.tree.map(lambda a: a[s], params_all)
            x = apply_stage(p, x)
        return x

    return jax.vmap(run_micro)(x_micro)
