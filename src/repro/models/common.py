"""Shared model building blocks: norms, rope, init, dtype policy."""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# Initialisation
# ---------------------------------------------------------------------------


def dense_init(key, shape: Tuple[int, ...], dtype, fan_in: Optional[int] = None):
    fan_in = fan_in if fan_in is not None else shape[0]
    std = 1.0 / math.sqrt(max(1, fan_in))
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------


def rmsnorm_init(d: int, dtype) -> jnp.ndarray:
    return jnp.zeros((d,), dtype)  # stored as (1 + w) * x_hat, gemma-style


def rmsnorm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    xn = xf * jax.lax.rsqrt(var + eps)
    return (xn * (1.0 + w.astype(jnp.float32))).astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embeddings — computed on the fly from positions so no
# (max_seq, hd/2) table is ever materialised (matters at 524k context).
# ---------------------------------------------------------------------------


def rope_sincos(positions: jnp.ndarray, head_dim: int, theta: float):
    """positions: (...,) int32 -> (cos, sin) of shape (..., head_dim//2)."""
    half = head_dim // 2
    freqs = jnp.exp(
        -math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jnp.ndarray, cos: jnp.ndarray, sin: jnp.ndarray):
    """x: (B, S, H, hd); cos/sin: (B, S, hd/2) or (S, hd/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    if cos.ndim == x.ndim - 2:          # (S, half) -> broadcast over B, H
        cos = cos[None, :, None, :]
        sin = sin[None, :, None, :]
    elif cos.ndim == x.ndim - 1:        # (B, S, half)
        cos = cos[:, :, None, :]
        sin = sin[:, :, None, :]
    dt = x.dtype
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate(
        [x1f * cos - x2f * sin, x2f * cos + x1f * sin], axis=-1)
    return out.astype(dt)


def activation(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu,
            "relu": jax.nn.relu}[name]


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    if cap and cap > 0:
        return jnp.tanh(x / cap) * cap
    return x
