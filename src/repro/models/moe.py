"""Mixture-of-Experts with capacity-bounded sort dispatch.

Expert parallelism: expert-stacked weights (E, D, F) shard E over the
``model`` mesh axis. Routing is *grouped*: tokens are routed independently
per group (groups align with the data-parallel batch shards), so the
argsort is batched over a sharded leading dim — no global sort, and the
dispatch reshard lowers to expert-parallel collectives instead of a full
gather.

Load-balancing aux loss (Switch-style) and router z-loss are returned so
the train step can add them.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import activation, dense_init


def moe_init(key, cfg: ModelConfig, dtype) -> Dict[str, Any]:
    m = cfg.moe
    d, e, f = cfg.d_model, m.n_experts, m.d_ff_expert
    ks = jax.random.split(key, 4)
    return {
        "router": dense_init(ks[0], (d, e), jnp.float32),
        "we_in": dense_init(ks[1], (e, d, f), dtype, fan_in=d),
        "we_gate": dense_init(ks[2], (e, d, f), dtype, fan_in=d),
        "we_out": dense_init(ks[3], (e, f, d), dtype, fan_in=f),
    }


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def moe_apply(params, x, cfg: ModelConfig, *, n_groups: int = 0,
              constrain_dispatch=None,
              dropless: bool = False) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    """x: (B, S, D) -> (y, aux). Groups default to the batch dim.

    ``dropless=True`` (serving modes) sizes every expert at the full
    token count so no token is ever dropped: capacity-bounded dispatch
    is a *training* throughput trade-off, and because the capacity
    depends on the sequence length it is non-causal — a dropped token
    would make prefill/decode diverge from the teacher-forced oracle.
    """
    m = cfg.moe
    b, s, d = x.shape
    e, k = m.n_experts, m.top_k
    act = activation(cfg.act_fn)

    g = n_groups or b
    n = b * s // g                      # tokens per group
    xg = x.reshape(g, n, d)

    logits = jnp.einsum("gnd,de->gne", xg.astype(jnp.float32),
                        params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)     # (g, n, k)
    gate_vals = gate_vals / jnp.clip(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # --- aux losses -------------------------------------------------------
    me = jnp.mean(probs, axis=1)                        # (g, e) mean prob
    ce = jnp.mean(
        jax.nn.one_hot(expert_ids, e, dtype=jnp.float32), axis=(1, 2))
    aux_loss = e * jnp.mean(jnp.sum(me * ce, axis=-1))
    z_loss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)

    # --- capacity-bounded sort dispatch ------------------------------------
    if dropless:
        cap = _round_up(n * k, 8)       # keep the TPU lane alignment
    else:
        cap = _round_up(int(math.ceil(k * n * m.capacity_factor / e)), 8)
        cap = min(cap, n * k)

    flat_expert = expert_ids.reshape(g, n * k)          # (g, nk)
    flat_token = jnp.tile(jnp.arange(n, dtype=jnp.int32)[:, None],
                          (1, k)).reshape(n * k)
    flat_gate = gate_vals.reshape(g, n * k)

    order = jnp.argsort(flat_expert, axis=-1, stable=True)      # (g, nk)
    sorted_expert = jnp.take_along_axis(flat_expert, order, axis=-1)
    sorted_token = flat_token[order]                            # (g, nk)
    sorted_gate = jnp.take_along_axis(flat_gate, order, axis=-1)

    # position within the expert's group
    group_start = jax.vmap(
        lambda se: jnp.searchsorted(se, jnp.arange(e, dtype=se.dtype)))(
            sorted_expert)                                      # (g, e)
    pos = jnp.arange(n * k, dtype=jnp.int32)[None, :] - \
        jnp.take_along_axis(group_start, sorted_expert, axis=-1)
    keep = pos < cap

    # gather tokens into (g, e, cap, d) — scatter with the expert dim
    # KEPT STRUCTURED: flattening (e*cap) hides the expert axis from
    # GSPMD, which then replicates the scatter and all-reduces
    # (g, nk, d)-sized buffers (256 GiB/step on olmoe, §Perf addendum);
    # 2-D indices + mode='drop' keep it shardable over e
    xk = jnp.take_along_axis(
        xg, sorted_token[..., None].astype(jnp.int32), axis=1)  # (g, nk, d)
    buf = jnp.zeros((g, e, cap, d), x.dtype)
    buf = jax.vmap(
        lambda bu, se, sp, xv: bu.at[se, sp].set(xv, mode="drop"))(
            buf, sorted_expert, pos, xk)
    if constrain_dispatch is not None:
        buf = constrain_dispatch(buf)

    # expert FFN (E sharded over model axis)
    h = jnp.einsum("gecd,edf->gecf", buf, params["we_in"])
    ga = jnp.einsum("gecd,edf->gecf", buf, params["we_gate"])
    h = h * act(ga)
    y = jnp.einsum("gecf,efd->gecd", h, params["we_out"])
    if constrain_dispatch is not None:
        y = constrain_dispatch(y)

    # combine back (clip dropped slots; their weight is zeroed below)
    yk = jax.vmap(
        lambda yb, se, sp: yb[se, jnp.minimum(sp, cap - 1)])(
            y, sorted_expert, pos)                              # (g, nk, d)
    w = (sorted_gate * keep).astype(x.dtype)[..., None]
    out = jnp.zeros((g, n, d), x.dtype)
    out = jax.vmap(lambda o, t, v: o.at[t].add(v))(
        out, sorted_token.astype(jnp.int32), yk * w)

    aux = {"moe_aux_loss": aux_loss * m.router_aux_weight,
           "moe_z_loss": z_loss * m.router_z_weight,
           "moe_drop_frac": 1.0 - jnp.mean(keep.astype(jnp.float32))}
    return out.reshape(b, s, d), aux
