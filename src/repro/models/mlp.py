"""Gated feed-forward (SwiGLU / GeGLU)."""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import activation, dense_init


def mlp_init(key, d_model: int, d_ff: int, dtype) -> Dict[str, Any]:
    ks = jax.random.split(key, 3)
    return {
        "w_in": dense_init(ks[0], (d_model, d_ff), dtype),
        "w_gate": dense_init(ks[1], (d_model, d_ff), dtype),
        "w_out": dense_init(ks[2], (d_ff, d_model), dtype),
    }


def mlp_apply(params, x, cfg: ModelConfig, constrain_ffn=None):
    act = activation(cfg.act_fn)
    h = jnp.einsum("bsd,df->bsf", x, params["w_in"])
    g = jnp.einsum("bsd,df->bsf", x, params["w_gate"])
    h = h * act(g)
    if constrain_ffn is not None:
        h = constrain_ffn(h)
    return jnp.einsum("bsf,fd->bsd", h, params["w_out"])
