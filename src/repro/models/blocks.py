"""Decoder blocks + the period decomposition used to scan over layers.

Every assigned architecture has a layer pattern that is periodic in the
layer index (gemma3: 5 local + 1 global, period 6; jamba: attention at
index 4 of each period-8 block with MoE on odd layers; all others:
period 1).  We exploit this to keep the lowered HLO small: parameters for
layer position ``p`` of each period are stacked over the periods and the
model scans over periods with a body containing exactly ``period`` layers
(+ an unrolled tail of ``n_layers % period`` layers).  This bounds the HLO
size by O(2 * period) layers regardless of depth — important for the
512-device dry-run compiles.

A layer's behaviour is fully determined by its *signature*
``(kind, is_moe, is_global)`` which is static per position-in-period.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.attention import attention_layer, attn_init, cache_shape
from repro.models.common import rmsnorm, rmsnorm_init
from repro.models.mamba import mamba_cache_shapes, mamba_init, mamba_layer
from repro.models.mlp import mlp_apply, mlp_init
from repro.models.moe import moe_apply, moe_init

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Period decomposition
# ---------------------------------------------------------------------------


def layer_signature(cfg: ModelConfig, i: int) -> Tuple[str, bool, bool]:
    return (cfg.layer_kind(i), cfg.layer_is_moe(i),
            cfg.layer_is_global_attn(i))


def find_period(cfg: ModelConfig) -> int:
    """Smallest p such that signature(i) == signature(i % p) for all i."""
    n = cfg.n_layers
    for p in range(1, n + 1):
        if all(layer_signature(cfg, i) == layer_signature(cfg, i % p)
               for i in range(n)):
            return p
    return n


@dataclass(frozen=True)
class PeriodPlan:
    period: int
    n_full: int        # number of scanned periods
    n_tail: int        # unrolled remainder layers

    @property
    def n_layers(self) -> int:
        return self.period * self.n_full + self.n_tail

    def tail_layer_idx(self, j: int) -> int:
        return self.period * self.n_full + j


def make_plan(cfg: ModelConfig) -> PeriodPlan:
    p = find_period(cfg)
    return PeriodPlan(period=p, n_full=cfg.n_layers // p,
                      n_tail=cfg.n_layers % p)


# ---------------------------------------------------------------------------
# Single-layer init / apply
# ---------------------------------------------------------------------------


def layer_has_ffn(cfg: ModelConfig, i: int) -> bool:
    """SSM-family blocks have no separate FFN; everything else does."""
    if cfg.family == "ssm":
        return False
    return True


def layer_init(key, cfg: ModelConfig, layer_idx: int, dtype) -> Params:
    kind, is_moe, _ = layer_signature(cfg, layer_idx)
    ks = jax.random.split(key, 2)
    p: Params = {"ln1": rmsnorm_init(cfg.d_model, dtype)}
    if kind == "attn":
        p["attn"] = attn_init(ks[0], cfg, dtype)
    else:
        p["ssm"] = mamba_init(ks[0], cfg, dtype)
    if layer_has_ffn(cfg, layer_idx):
        p["ln2"] = rmsnorm_init(cfg.d_model, dtype)
        if is_moe:
            p["moe"] = moe_init(ks[1], cfg, dtype)
        else:
            p["mlp"] = mlp_init(ks[1], cfg.d_model, cfg.d_ff, dtype)
    return p


def layer_apply(
    params: Params,
    x: jnp.ndarray,
    *,
    cfg: ModelConfig,
    layer_idx: int,
    mode: str,
    cache: Optional[Params] = None,
    write_pos=None,
    q_chunk: int = 256,
    constrain: Optional[Callable[[jnp.ndarray, str], jnp.ndarray]] = None,
    max_len: int = 0,
    delta_cache: bool = False,
) -> Tuple[jnp.ndarray, Optional[Params], Dict[str, jnp.ndarray]]:
    """One decoder block.  Returns (x, new_cache, aux_losses)."""
    kind, is_moe, _ = layer_signature(cfg, layer_idx)
    cst = constrain or (lambda v, _name: v)
    aux: Dict[str, jnp.ndarray] = {}

    h = rmsnorm(x, params["ln1"], cfg.norm_eps)
    if kind == "attn":
        y, new_cache = attention_layer(
            params["attn"], h, cfg=cfg, layer_idx=layer_idx, mode=mode,
            cache=cache, write_pos=write_pos, q_chunk=q_chunk,
            constrain_kv=lambda v: cst(v, "kv"), max_len=max_len,
            constrain=cst, delta_cache=delta_cache)
    else:
        y, new_cache = mamba_layer(
            params["ssm"], h, cfg=cfg, mode=mode, cache=cache)
    x = cst(x + y, "hidden")

    if layer_has_ffn(cfg, layer_idx):
        h = rmsnorm(x, params["ln2"], cfg.norm_eps)
        if is_moe:
            y, aux = moe_apply(
                params["moe"], h, cfg,
                constrain_dispatch=lambda v: cst(v, "dispatch"),
                dropless=mode != "train")
        else:
            y = mlp_apply(params["mlp"], h, cfg,
                          constrain_ffn=lambda v: cst(v, "ffn"))
        x = cst(x + y, "hidden")
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Cache construction (abstract + concrete) for one layer
# ---------------------------------------------------------------------------


def layer_cache_struct(cfg: ModelConfig, layer_idx: int, batch: int,
                       max_len: int, kv_dtype=jnp.bfloat16):
    """ShapeDtypeStruct pytree for this layer's decode cache."""
    kind, _, _ = layer_signature(cfg, layer_idx)
    if kind == "attn":
        shp = cache_shape(cfg, layer_idx, batch, max_len)
        return {"k": jax.ShapeDtypeStruct(shp, kv_dtype),
                "v": jax.ShapeDtypeStruct(shp, kv_dtype)}
    shapes = mamba_cache_shapes(cfg, batch)
    return {"ssm": jax.ShapeDtypeStruct(shapes["ssm"], jnp.float32),
            "conv": jax.ShapeDtypeStruct(shapes["conv"], kv_dtype)}


def layer_cache_init(cfg: ModelConfig, layer_idx: int, batch: int,
                     max_len: int, kv_dtype=jnp.bfloat16):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        layer_cache_struct(cfg, layer_idx, batch, max_len,
                                           kv_dtype))
