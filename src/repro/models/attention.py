"""GQA attention: chunked full-context, sliding-window, and decode paths.

Memory discipline (DESIGN.md §6):
  * no (Sq, Sk) score tensor is materialised for the full sequence — the q
    dimension is processed in chunks via ``lax.scan`` (trip counts are
    recovered by the while-aware HLO cost parser, ``repro.analysis.hlo``);
  * sliding-window layers slice a fixed-width KV extent per q-chunk
    (``dynamic_slice``), so local attention is O(S * window) exactly — this
    is what makes the gemma3 long-context cells sub-quadratic;
  * decode attends one query position against the (possibly sequence-
    sharded) KV cache; softmax statistics combine via XLA collectives.

KV caches for sliding-window layers are ring buffers of size ``window``.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import (
    apply_rope, dense_init, rmsnorm, rmsnorm_init, rope_sincos, softcap,
)

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def attn_init(key, cfg: ModelConfig, dtype) -> Dict[str, Any]:
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], (d, h, hd), dtype, fan_in=d),
        "wk": dense_init(ks[1], (d, kv, hd), dtype, fan_in=d),
        "wv": dense_init(ks[2], (d, kv, hd), dtype, fan_in=d),
        "wo": dense_init(ks[3], (h, hd, d), dtype, fan_in=h * hd),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), dtype)
        p["bk"] = jnp.zeros((kv, hd), dtype)
        p["bv"] = jnp.zeros((kv, hd), dtype)
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(hd, dtype)
        p["k_norm"] = rmsnorm_init(hd, dtype)
    return p


# ---------------------------------------------------------------------------
# Core scoring helper (GQA): one q-chunk against a KV extent.
# ---------------------------------------------------------------------------


def _gqa_attend(q, k, v, mask, scale: float, cap: float):
    """q: (B, Sq, KV, G, hd); k/v: (B, Sk, KV, hd); mask: (B?, Sq, Sk) bool.

    Returns (B, Sq, KV, G, hd). Scores accumulate in f32.
    """
    s = jnp.einsum("bqkgh,bskh->bkgqs", q, k,
                   preferred_element_type=jnp.float32) * scale
    s = softcap(s, cap)
    if mask is not None:
        if mask.ndim == 2:
            mask = mask[None]
        s = jnp.where(mask[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskh->bqkgh", p.astype(v.dtype), v,
                     preferred_element_type=jnp.float32)
    return out.astype(v.dtype)


def _split_heads(q, n_kv: int):
    b, s, h, hd = q.shape
    return q.reshape(b, s, n_kv, h // n_kv, hd)


def _merge_heads(o):
    b, s, kv, g, hd = o.shape
    return o.reshape(b, s, kv * g, hd)


# ---------------------------------------------------------------------------
# Full-context causal attention, q-chunked.
# ---------------------------------------------------------------------------


def causal_attention(q, k, v, *, q_positions, k_positions, scale, cap=0.0,
                     q_chunk: int = 256, k_valid=None):
    """q: (B,Sq,H,hd) | k,v: (B,Sk,KV,hd). Positions are absolute (int32).

    k_valid: optional (B, Sk) bool — entries beyond the written cache length.
    """
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    qg = _split_heads(q, kvh)
    if sq <= q_chunk:
        mask = k_positions[None, None, :] <= q_positions[None, :, None]
        mask = jnp.broadcast_to(mask, (b, sq, k.shape[1]))
        if k_valid is not None:
            mask = mask & k_valid[:, None, :]
        return _merge_heads(_gqa_attend(qg, k, v, mask, scale, cap))

    n_chunks = sq // q_chunk
    assert sq % q_chunk == 0, (sq, q_chunk)
    qg = qg.reshape(b, n_chunks, q_chunk, kvh, h // kvh, hd)
    qpos = q_positions.reshape(n_chunks, q_chunk)

    # checkpoint per chunk: without it the backward of the scan SAVES the
    # (b, kv, g, q_chunk, Sk) attention probabilities of every chunk into
    # a stacked residual (the dominant HBM traffic of the train cells —
    # see EXPERIMENTS.md §Perf iteration 2); recomputing them per-chunk
    # in the backward turns a cross-scan save/load into chunk-local temps
    @jax.checkpoint
    def chunk_attend(qc, qp):
        mask = k_positions[None, None, :] <= qp[None, :, None]
        mask = jnp.broadcast_to(mask, (b, q_chunk, k.shape[1]))
        if k_valid is not None:
            mask = mask & k_valid[:, None, :]
        return _gqa_attend(qc, k, v, mask, scale, cap)

    def body(_, inp):
        qc, qp = inp
        return None, chunk_attend(qc, qp)

    _, outs = jax.lax.scan(body, None,
                           (jnp.moveaxis(qg, 1, 0), qpos))
    outs = jnp.moveaxis(outs, 0, 1).reshape(b, sq, kvh, h // kvh, hd)
    return _merge_heads(outs)


# ---------------------------------------------------------------------------
# Sliding-window attention via OVERLAPPING BLOCKS (exact O(S*W)).
#
# q is reshaped to (B, nb, block, ...) and k/v are gathered into
# (B, nb, block+window, ...) overlapping extents, so every block's
# attention is a fully LOCAL batched einsum — the blocks dim shards over
# the "model" mesh axis (constrain "blocked"), which removes both the
# per-chunk scan residual stacks AND every intra-attention collective
# that the seq-sharded-KV formulation paid (EXPERIMENTS.md §Perf it.2).
# The band mask is static per (block-row, extent-col) up to edge
# validity, shared by all blocks.
# ---------------------------------------------------------------------------


def window_attention(q, k, v, *, window: int, scale, cap=0.0,
                     q_chunk: int = 256, constrain=None):
    """Self-attention where q index i attends k indices (i-window, i].

    q: (B,S,H,hd); k,v: (B,S,KV,hd) aligned with q.
    """
    b, s, h, hd = q.shape
    kvh = k.shape[2]
    if s <= window + q_chunk:
        pos = jnp.arange(s, dtype=jnp.int32)
        return causal_window_fallback(q, k, v, pos, window, scale, cap,
                                      q_chunk)
    block = q_chunk
    assert s % block == 0
    nb = s // block
    ext = window + block
    cst = constrain or (lambda x, _n: x)

    # (nb, ext) absolute k row index per block, clipped at the left edge
    starts = jnp.arange(nb, dtype=jnp.int32) * block - window
    idx = starts[:, None] + jnp.arange(ext, dtype=jnp.int32)[None, :]
    valid_edge = idx >= 0
    idx = jnp.maximum(idx, 0)

    qb = _split_heads(q, kvh).reshape(b, nb, block, kvh, h // kvh, hd)
    kb = jnp.take(k, idx, axis=1)          # (B, nb, ext, KV, hd)
    vb = jnp.take(v, idx, axis=1)
    qb = cst(qb, "blocked_q")
    kb = cst(kb, "blocked_kv")
    vb = cst(vb, "blocked_kv")

    # band mask: qpos = n*block + qi ; kpos = n*block - window + ei
    qi = jnp.arange(block, dtype=jnp.int32)
    ei = jnp.arange(ext, dtype=jnp.int32)
    rel = ei[None, :] - window - qi[:, None]      # kpos - qpos
    band = (rel <= 0) & (rel > -window)           # (block, ext)
    mask = band[None, :, :] & valid_edge[:, None, :]   # (nb, block, ext)

    sc = jnp.einsum("bnqkgh,bnekh->bnkgqe", qb, kb,
                    preferred_element_type=jnp.float32) * scale
    sc = softcap(sc, cap)
    sc = jnp.where(mask[None, :, None, None], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    o = jnp.einsum("bnkgqe,bnekh->bnqkgh", p.astype(vb.dtype), vb,
                   preferred_element_type=jnp.float32).astype(v.dtype)
    o = cst(o, "blocked_q")
    return o.reshape(b, s, kvh, h // kvh, hd).reshape(b, s, h, hd)


def causal_window_fallback(q, k, v, positions, window, scale, cap, q_chunk):
    """Small-S path: full mask with window band."""
    b, s, h, hd = q.shape
    kvh = k.shape[2]
    qg = _split_heads(q, kvh)
    mask = (positions[None, :] <= positions[:, None]) & \
           (positions[None, :] > positions[:, None] - window)
    mask = jnp.broadcast_to(mask[None], (b, s, s))
    return _merge_heads(_gqa_attend(qg, k, v, mask, scale, cap))


# ---------------------------------------------------------------------------
# Decode: one query position against the cache.
# ---------------------------------------------------------------------------


def decode_attention(q1, cache_k, cache_v, *, write_pos, scale, cap=0.0,
                     ring: bool = False, window: int = 0):
    """q1: (B,1,H,hd); cache_k/v: (B,Sbuf,KV,hd); write_pos: scalar int32 —
    the absolute position just written (valid entries: <= write_pos).

    ring=True: the buffer is a ring of size `window` holding the last
    `window` positions — everything currently in it is valid once
    write_pos >= window-1.
    """
    b, sbuf = cache_k.shape[0], cache_k.shape[1]
    idx = jnp.arange(sbuf, dtype=jnp.int32)
    if ring:
        valid = (idx <= write_pos) | (write_pos >= sbuf)
    else:
        valid = idx <= write_pos
        if window:
            valid = valid & (idx > write_pos - window)
    kvh = cache_k.shape[2]
    qg = _split_heads(q1, kvh)
    mask = jnp.broadcast_to(valid[None, None, :], (b, 1, sbuf))
    return _merge_heads(_gqa_attend(qg, cache_k, cache_v, mask, scale, cap))


def decode_attention_delta(q1, cache_k, cache_v, k_new, v_new, *,
                           write_pos, scale, cap=0.0, ring: bool = False,
                           window: int = 0):
    """Decode WITHOUT materializing an updated cache: attends the OLD
    cache (entries < write_pos) plus the new token's (k,v) via a
    two-part softmax.  The caller appends (k_new, v_new) to the cache
    out-of-band (one token-sized dynamic-update-slice after the layer
    scan, instead of re-emitting the whole cache through the scan — the
    full-cache copy was the dominant decode traffic, EXPERIMENTS.md
    §Perf C3).

    q1, k_new, v_new: (B, 1, H|KV, hd).  Returns (B, 1, H, hd).
    """
    b, sbuf, kvh, hd = cache_k.shape
    idx = jnp.arange(sbuf, dtype=jnp.int32)
    if ring:
        slot = write_pos % sbuf
        # cache holds positions write_pos-sbuf .. write_pos-1; the slot
        # about to be overwritten (oldest) leaves the window
        valid = ((idx < write_pos) | (write_pos >= sbuf)) & (idx != slot)
    else:
        valid = idx < write_pos
        if window:
            valid = valid & (idx > write_pos - window)
    qg = _split_heads(q1, kvh)                       # (B,1,KV,G,hd)

    s_old = jnp.einsum("bqkgh,bskh->bkgqs", qg, cache_k,
                       preferred_element_type=jnp.float32) * scale
    s_new = jnp.einsum("bqkgh,bskh->bkgqs", qg, k_new,
                       preferred_element_type=jnp.float32) * scale
    s_old = softcap(s_old, cap)
    s_new = softcap(s_new, cap)
    s_old = jnp.where(valid[None, None, None, None, :], s_old, NEG_INF)

    m = jnp.maximum(jnp.max(s_old, axis=-1, keepdims=True), s_new)
    p_old = jnp.exp(s_old - m)
    p_new = jnp.exp(s_new - m)
    denom = jnp.sum(p_old, axis=-1, keepdims=True) + p_new
    o = jnp.einsum("bkgqs,bskh->bqkgh",
                   (p_old / denom).astype(cache_v.dtype), cache_v,
                   preferred_element_type=jnp.float32)
    o = o + jnp.einsum("bkgqs,bskh->bqkgh",
                       (p_new / denom).astype(cache_v.dtype), v_new,
                       preferred_element_type=jnp.float32)
    return _merge_heads(o.astype(cache_v.dtype))


# ---------------------------------------------------------------------------
# Layer entry point
# ---------------------------------------------------------------------------


ONE_SHOT_MAX_S = 8192   # train-path: full-context attention without
                        # q-chunking below this S (scores fit per-device
                        # once q is sequence-sharded over "model")


def attention_layer(params, x, *, cfg: ModelConfig, layer_idx: int,
                    mode: str, cache: Optional[Dict[str, Any]] = None,
                    write_pos=None, q_chunk: int = 256,
                    constrain_kv=None, max_len: int = 0, constrain=None,
                    delta_cache: bool = False):
    """x: (B, S, D) -> (out (B,S,D), new_cache or None).

    mode: "train" | "prefill" | "decode".
    cache: {"k": (B,Sbuf,KV,hd), "v": ...} for prefill (written) and decode.
    constrain_kv: optional fn applied to freshly computed k/v (sharding).
    constrain: optional name-based sharding hook (see train.step) used by
    the blocked/one-shot train paths.
    """
    b, s, d = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    is_global = cfg.layer_is_global_attn(layer_idx)
    window = 0 if is_global else cfg.sliding_window
    theta = cfg.rope_theta if (is_global or not cfg.rope_theta_local) \
        else cfg.rope_theta_local
    scale = 1.0 / math.sqrt(hd)

    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"])
    if cfg.qkv_bias:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    if cfg.qk_norm:
        q = rmsnorm(q, params["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, params["k_norm"], cfg.norm_eps)

    if mode in ("train", "prefill"):
        positions = jnp.arange(s, dtype=jnp.int32)
        if theta:
            cos, sin = rope_sincos(positions, hd, theta)
            q = apply_rope(q, cos, sin)
            k = apply_rope(k, cos, sin)
        cst = constrain or (lambda v_, _n: v_)
        if window:
            # overlapping-blocks path: fully local compute, blocks dim
            # sharded over "model" via the constrain hook
            out = window_attention(q, k, v, window=window, scale=scale,
                                   cap=cfg.attn_logit_softcap,
                                   q_chunk=q_chunk, constrain=cst)
        elif mode == "train" and s <= ONE_SHOT_MAX_S:
            # one-shot full attention, q sequence-sharded over "model",
            # k/v gathered once per layer: no per-chunk collectives, no
            # scan residual stacks (EXPERIMENTS.md §Perf iteration 3)
            qs = cst(q, "q_seq")
            kr = cst(k, "kv_rep")
            vr = cst(v, "kv_rep")
            mask = positions[None, :] <= positions[:, None]
            mask = jnp.broadcast_to(mask[None], (b, s, s))
            out = _merge_heads(_gqa_attend(
                _split_heads(qs, kv), kr, vr, mask, scale,
                cfg.attn_logit_softcap))
            out = cst(out, "q_seq")
        else:
            if constrain_kv is not None:
                k, v = constrain_kv(k), constrain_kv(v)
            out = causal_attention(q, k, v, q_positions=positions,
                                   k_positions=positions, scale=scale,
                                   cap=cfg.attn_logit_softcap,
                                   q_chunk=q_chunk)
        new_cache = None
        if mode == "prefill":
            sbuf = max(max_len, s)
            if window:
                sbuf = min(window, sbuf)
            if window and window < s:
                # keep the last `window` positions as the ring buffer
                k_tail = k[:, s - window:]
                v_tail = v[:, s - window:]
                # ring layout: slot = pos % window
                roll = (-(s % window)) % window
                new_cache = {"k": jnp.roll(k_tail, roll, axis=1),
                             "v": jnp.roll(v_tail, roll, axis=1)}
            elif sbuf == s:
                new_cache = {"k": k, "v": v}
            else:
                # write the first s positions of a max_len-sized buffer so
                # decode can append without clobbering prefill entries
                zk = jnp.zeros((b, sbuf) + k.shape[2:], k.dtype)
                zv = jnp.zeros((b, sbuf) + v.shape[2:], v.dtype)
                new_cache = {
                    "k": jax.lax.dynamic_update_slice_in_dim(zk, k, 0, 1),
                    "v": jax.lax.dynamic_update_slice_in_dim(zv, v, 0, 1)}
    else:  # decode
        assert cache is not None and write_pos is not None
        if theta:
            cos, sin = rope_sincos(write_pos[None].astype(jnp.int32), hd,
                                   theta)
            q = apply_rope(q, cos[None], sin[None])
            k = apply_rope(k, cos[None], sin[None])
        sbuf = cache["k"].shape[1]
        ring = bool(window) and sbuf == window
        if delta_cache:
            # two-part attention over (old cache, new token); the caller
            # applies the one-token write after the layer scan
            out = decode_attention_delta(
                q, cache["k"], cache["v"], k, v, write_pos=write_pos,
                scale=scale, cap=cfg.attn_logit_softcap, ring=ring,
                window=0 if ring else window)
            new_cache = {"k_new": k, "v_new": v}
        else:
            slot = jnp.where(ring, write_pos % sbuf,
                             jnp.minimum(write_pos, sbuf - 1)
                             ).astype(jnp.int32)
            ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot,
                                                     axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot,
                                                     axis=1)
            out = decode_attention(q, ck, cv, write_pos=write_pos,
                                   scale=scale,
                                   cap=cfg.attn_logit_softcap, ring=ring,
                                   window=0 if ring else window)
            new_cache = {"k": ck, "v": cv}

    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"])
    return y, new_cache


def cache_shape(cfg: ModelConfig, layer_idx: int, batch: int,
                max_len: int) -> Tuple[int, int, int, int]:
    """(B, Sbuf, KV, hd) for this layer's cache."""
    is_global = cfg.layer_is_global_attn(layer_idx)
    window = 0 if is_global else cfg.sliding_window
    sbuf = max_len if not window else min(window, max_len)
    return (batch, sbuf, cfg.n_kv_heads, cfg.head_dim)
