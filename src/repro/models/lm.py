"""Model assembly: embeddings + period-scanned decoder stack + LM head.

Entry points
------------
``init_params(cfg, key)``            parameter pytree (scan-stacked).
``forward(params, tokens, ...)``     -> (hidden, caches, aux) for
                                     mode in {"train", "prefill", "decode"}.
``unembed_logits(params, h, cfg)``   LM-head projection (callers chunk it).
``init_cache / cache_struct``        decode caches matching the scan layout.
``param_specs / cache_specs``        PartitionSpec pytrees from ShardingRules.

Parameter layout (see blocks.py for the period decomposition)::

    {"embed": (Vp, D), "unembed": (Vp, D)?, "final_norm": (D,),
     "scan": {"p0": <stacked over n_full>, ..., "p<period-1>": ...},
     "tail": {"t0": <single layer>, ...}}
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import blocks
from repro.models.blocks import PeriodPlan, make_plan
from repro.models.common import dtype_of, embed_init, rmsnorm, rmsnorm_init
from repro.parallel.sharding import ShardingRules

Params = Dict[str, Any]

_MOE_AUX_KEYS = ("moe_aux_loss", "moe_z_loss", "moe_drop_frac")


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def init_params(cfg: ModelConfig, key) -> Params:
    dtype = dtype_of(cfg.param_dtype)
    plan = make_plan(cfg)
    k_embed, k_unembed, k_layers = jax.random.split(key, 3)
    params: Params = {
        "embed": embed_init(k_embed, (cfg.padded_vocab, cfg.d_model), dtype),
        "final_norm": rmsnorm_init(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = embed_init(
            k_unembed, (cfg.padded_vocab, cfg.d_model), dtype)

    layer_keys = jax.random.split(k_layers, cfg.n_layers)

    scan_groups: Params = {}
    for p in range(plan.period if plan.n_full else 0):
        stack = [blocks.layer_init(layer_keys[r * plan.period + p], cfg,
                                   r * plan.period + p, dtype)
                 for r in range(plan.n_full)]
        scan_groups[f"p{p}"] = jax.tree.map(
            lambda *ls: jnp.stack(ls), *stack)
    if scan_groups:
        params["scan"] = scan_groups

    tail: Params = {}
    for j in range(plan.n_tail):
        idx = plan.tail_layer_idx(j)
        tail[f"t{j}"] = blocks.layer_init(layer_keys[idx], cfg, idx, dtype)
    if tail:
        params["tail"] = tail
    return params


# ---------------------------------------------------------------------------
# Caches (concrete zeros + ShapeDtypeStruct views, matching scan layout)
# ---------------------------------------------------------------------------


def cache_struct(cfg: ModelConfig, batch: int, max_len: int,
                 kv_dtype=jnp.bfloat16) -> Params:
    plan = make_plan(cfg)
    out: Params = {}
    if plan.n_full:
        grp = {}
        for p in range(plan.period):
            one = blocks.layer_cache_struct(cfg, p, batch, max_len, kv_dtype)
            grp[f"p{p}"] = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct((plan.n_full,) + s.shape,
                                               s.dtype), one)
        out["scan"] = grp
    if plan.n_tail:
        out["tail"] = {
            f"t{j}": blocks.layer_cache_struct(
                cfg, plan.tail_layer_idx(j), batch, max_len, kv_dtype)
            for j in range(plan.n_tail)}
    return out


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               kv_dtype=jnp.bfloat16) -> Params:
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        cache_struct(cfg, batch, max_len, kv_dtype))


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _zero_aux(cfg: ModelConfig) -> Dict[str, jnp.ndarray]:
    if cfg.moe is None:
        return {}
    return {k: jnp.zeros((), jnp.float32) for k in _MOE_AUX_KEYS}


def _acc_aux(acc, aux):
    for k, v in aux.items():
        acc[k] = acc[k] + v.astype(jnp.float32)
    return acc


def _apply_kv_deltas(cfg: ModelConfig, plan, old_scan: Optional[Params],
                     emitted: Params, write_pos) -> Params:
    """Batched one-token cache writes for the scanned attention layers.

    emitted[p] is either {"k_new","v_new"} stacks (n_full, B, 1, KV, hd)
    for attention positions, or the full new state pytree for SSM
    positions (small, intrinsically rewritten each step)."""
    out: Params = {}
    for p_key, grp in emitted.items():
        if not (isinstance(grp, dict) and "k_new" in grp):
            out[p_key] = grp
            continue
        p = int(p_key[1:])
        old = old_scan[p_key]
        sbuf = old["k"].shape[2]
        window = 0 if cfg.layer_is_global_attn(p) else cfg.sliding_window
        ring = bool(window) and sbuf == window
        slot = jnp.where(ring, write_pos % sbuf,
                         jnp.minimum(write_pos, sbuf - 1)).astype(jnp.int32)
        zero = jnp.int32(0)
        starts = (zero, zero, slot, zero, zero)
        out[p_key] = {
            "k": jax.lax.dynamic_update_slice(
                old["k"], grp["k_new"].astype(old["k"].dtype), starts),
            "v": jax.lax.dynamic_update_slice(
                old["v"], grp["v_new"].astype(old["v"].dtype), starts),
        }
    return out


def embed_tokens(params: Params, tokens: jnp.ndarray, cfg: ModelConfig,
                 frontend: Optional[Dict[str, jnp.ndarray]] = None):
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.scale_embed:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
    if frontend:
        if cfg.frontend == "audio" and "frame_embeds" in frontend:
            x = x + frontend["frame_embeds"].astype(x.dtype)
        elif cfg.frontend == "vlm" and "prefix_embeds" in frontend:
            pe = frontend["prefix_embeds"].astype(x.dtype)
            x = jax.lax.dynamic_update_slice_in_dim(x, pe, 0, axis=1)
    return x


def unembed_logits(params: Params, h: jnp.ndarray, cfg: ModelConfig):
    w = params["embed"] if cfg.tie_embeddings else params["unembed"]
    return jnp.einsum("bsd,vd->bsv", h, w,
                      preferred_element_type=jnp.float32)


def forward(
    params: Params,
    tokens: jnp.ndarray,
    *,
    cfg: ModelConfig,
    mode: str = "train",                  # train | prefill | decode
    caches: Optional[Params] = None,
    write_pos=None,                       # scalar int32 (decode)
    frontend: Optional[Dict[str, jnp.ndarray]] = None,
    constrain: Optional[Callable[[jnp.ndarray, str], jnp.ndarray]] = None,
    remat: str = "none",                  # none | layer
    q_chunk: int = 256,
    max_len: int = 0,                     # cache capacity (prefill)
) -> Tuple[jnp.ndarray, Optional[Params], Dict[str, jnp.ndarray]]:
    """tokens: (B, S) int32 -> (hidden (B,S,D), caches', aux)."""
    plan = make_plan(cfg)
    cst = constrain or (lambda v, _n: v)
    x = cst(embed_tokens(params, tokens, cfg, frontend), "hidden")
    aux = _zero_aux(cfg)

    # decode: scanned attention layers emit one-token (k,v) DELTAS, and
    # the stacked caches are updated with a single batched write after
    # the scan — re-emitting whole caches through scan ys copied the
    # entire KV cache every step (EXPERIMENTS.md §Perf C3)
    delta = mode == "decode"

    def one_period(x, period_params, period_caches):
        """Apply layers p0..p<period-1>; returns (x, new_caches, aux)."""
        new_caches: Params = {}
        a = _zero_aux(cfg)
        for p in range(plan.period):
            c = period_caches[f"p{p}"] if period_caches is not None else None
            x, nc, la = blocks.layer_apply(
                period_params[f"p{p}"], x, cfg=cfg, layer_idx=p, mode=mode,
                cache=c, write_pos=write_pos, q_chunk=q_chunk, constrain=cst,
                max_len=max_len, delta_cache=delta)
            if nc is not None:
                new_caches[f"p{p}"] = nc
            a = _acc_aux(a, la)
        return x, new_caches, a

    if plan.n_full:
        want_cache = mode in ("prefill", "decode")

        def body(carry, xs):
            x, a = carry
            pp = xs["params"]
            pc = xs.get("cache")
            x, nc, la = one_period(x, pp, pc)
            return (x, _acc_aux(a, la)), (nc if want_cache else None)

        if remat == "layer" and mode == "train":
            body = jax.checkpoint(body)

        xs: Params = {"params": params["scan"]}
        if want_cache:
            xs["cache"] = (caches or {}).get("scan")
        (x, aux), scan_caches = jax.lax.scan(body, (x, aux), xs)
    else:
        scan_caches = None

    tail_caches: Params = {}
    for j in range(plan.n_tail):
        idx = plan.tail_layer_idx(j)
        c = None
        if caches is not None and "tail" in caches:
            c = caches["tail"][f"t{j}"]
        x, nc, la = blocks.layer_apply(
            params["tail"][f"t{j}"], x, cfg=cfg, layer_idx=idx, mode=mode,
            cache=c, write_pos=write_pos, q_chunk=q_chunk, constrain=cst,
            max_len=max_len)
        if nc is not None:
            tail_caches[f"t{j}"] = nc
        aux = _acc_aux(aux, la)

    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    x = cst(x, "hidden")

    if delta and scan_caches is not None:
        scan_caches = _apply_kv_deltas(cfg, plan, (caches or {}).get("scan"),
                                       scan_caches, write_pos)

    new_caches: Optional[Params] = None
    if mode in ("prefill", "decode"):
        new_caches = {}
        if scan_caches is not None:
            new_caches["scan"] = scan_caches
        if tail_caches:
            new_caches["tail"] = tail_caches
    if cfg.moe is not None and cfg.n_layers:
        # means over MoE layers (drop_frac is a mean; losses stay sums
        # scaled by their weights already applied per layer)
        n_moe = sum(1 for i in range(cfg.n_layers) if cfg.layer_is_moe(i))
        if n_moe:
            aux["moe_drop_frac"] = aux["moe_drop_frac"] / n_moe
    return x, new_caches, aux


# ---------------------------------------------------------------------------
# PartitionSpecs for params and caches
# ---------------------------------------------------------------------------


def _leaf_path(kp) -> str:
    parts = []
    for k in kp:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def param_specs(rules: ShardingRules, params: Params):
    """PartitionSpec pytree; scan-stacked leaves get a leading None axis."""
    from jax.sharding import PartitionSpec as P

    def spec_for(kp, leaf):
        path = _leaf_path(kp)
        if path.startswith("scan/"):
            base = rules.param_spec(path, leaf.shape[1:])
            return P(None, *tuple(base))
        return rules.param_spec(path, leaf.shape)

    return jax.tree_util.tree_map_with_path(spec_for, params)


def cache_specs(rules: ShardingRules, struct: Params):
    """PartitionSpec pytree for a cache pytree (concrete or structs)."""
    from jax.sharding import PartitionSpec as P

    def spec_for(kp, leaf):
        path = _leaf_path(kp)
        stacked = path.startswith("scan/")
        shape = leaf.shape[1:] if stacked else leaf.shape
        leaf_name = path.split("/")[-1]
        if leaf_name in ("k", "v"):
            base = rules.kv_cache_spec()           # (B, S, KV, hd)
        elif leaf_name == "ssm":
            base = rules.ssm_state_spec()          # (B, H, P, N)
        elif leaf_name == "conv":
            base = P(rules.batch if rules.batch else None, None,
                     _maybe_axis(rules, shape[-1]))
        else:
            base = P(*([None] * len(shape)))
        return P(None, *tuple(base)) if stacked else base

    return jax.tree_util.tree_map_with_path(spec_for, struct)


def _maybe_axis(rules: ShardingRules, dim: int):
    from repro.parallel.sharding import _maybe
    return _maybe(rules.tp, dim, rules.mesh)
