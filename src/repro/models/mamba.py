"""Mamba-2 (SSD, state-space duality) layers.

Training/prefill uses the chunked SSD algorithm (arXiv:2405.21060 §6):
within-chunk "attention-like" matmuls (MXU-friendly — this is the
hardware adaptation of the selective scan) plus a ``lax.scan`` recurrence
over chunk states. Decode is the O(1) recurrent update.

State layout: (B, H, P, N) with H = ssm heads (TP over "model"),
P = head_dim, N = d_state. Conv cache: (B, K-1, conv_channels).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.common import dense_init, rmsnorm

Params = Dict[str, Any]


def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d = cfg.d_model
    di = s.d_inner(d)
    nh = s.n_heads(d)
    return s, d, di, nh, s.n_groups, s.d_state, s.d_conv, s.head_dim


def mamba_init(key, cfg: ModelConfig, dtype) -> Params:
    s, d, di, nh, ng, ds, k, hp = _dims(cfg)
    conv_ch = di + 2 * ng * ds
    proj_out = 2 * di + 2 * ng * ds + nh
    ks = jax.random.split(key, 4)
    dt = jnp.exp(jax.random.uniform(ks[2], (nh,), jnp.float32) *
                 (jnp.log(0.1) - jnp.log(0.001)) + jnp.log(0.001))
    return {
        "w_zxbcdt": dense_init(ks[0], (d, proj_out), dtype),
        "conv_w": dense_init(ks[1], (k, conv_ch), dtype, fan_in=k),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.arange(1, nh + 1, dtype=jnp.float32)),
        "dt_bias": (dt + jnp.log(-jnp.expm1(-dt))).astype(jnp.float32),
        "ssm_D": jnp.ones((nh,), jnp.float32),
        "ssm_norm": jnp.zeros((di,), dtype),
        "w_ssm_out": dense_init(ks[3], (di, d), dtype),
    }


def _split_proj(zxbcdt, cfg: ModelConfig):
    s, d, di, nh, ng, ds, k, hp = _dims(cfg)
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + di + 2 * ng * ds]
    dt = zxbcdt[..., -nh:]
    return z, xbc, dt


def _causal_conv(xbc, conv_w, conv_b, prev: Optional[jnp.ndarray] = None):
    """Depthwise causal conv along S. xbc: (B,S,CH); conv_w: (K,CH).

    prev: optional (B, K-1, CH) history prepended (decode/chunked prefill).
    Returns (out (B,S,CH), tail (B,K-1,CH))."""
    k = conv_w.shape[0]
    if prev is None:
        prev = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[-1]), xbc.dtype)
    xp = jnp.concatenate([prev, xbc], axis=1)
    out = sum(xp[:, i:i + xbc.shape[1]] * conv_w[i] for i in range(k))
    out = jax.nn.silu(out + conv_b)
    tail = xp[:, xp.shape[1] - (k - 1):]
    return out, tail


# ---------------------------------------------------------------------------
# Chunked SSD (train / prefill)
# ---------------------------------------------------------------------------


def ssd_chunked(x, dt, A, B, C, chunk: int, init_state=None):
    """x: (B,S,H,P); dt: (B,S,H); A: (H,) (negative); B,C: (B,S,G,N).

    Returns (y (B,S,H,P), final_state (B,H,P,N)). f32 internals.
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    hg = h // g
    nc = s // chunk
    assert s % chunk == 0, (s, chunk)
    xc = x.reshape(b, nc, chunk, h, p).astype(jnp.float32)
    dtc = dt.reshape(b, nc, chunk, h)
    Bc = B.reshape(b, nc, chunk, g, n).astype(jnp.float32)
    Cc = C.reshape(b, nc, chunk, g, n).astype(jnp.float32)

    da = dtc * A  # (b, nc, T, h)
    seg = jnp.cumsum(da, axis=2)                     # (b,nc,T,h)
    seg_last = seg[:, :, -1:]                        # (b,nc,1,h)

    # within-chunk (diagonal block) — attention-like
    # L[i,j] = exp(seg_i - seg_j) for i >= j
    li = seg[:, :, :, None, :] - seg[:, :, None, :, :]   # (b,nc,T,T,h)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    L = jnp.where(mask[None, None, :, :, None], jnp.exp(li), 0.0)
    # scores G[i,j] per head: C_i . B_j  (group-broadcast to heads)
    Gm = jnp.einsum("bctgn,bcsgn->bctsg", Cc, Bc)        # (b,nc,T,T,g)
    Gm = jnp.repeat(Gm, hg, axis=-1)                     # heads
    M = Gm * L * dtc[:, :, None, :, :]                   # weight dt_j
    y_diag = jnp.einsum("bctsh,bcshp->bcthp", M, xc)

    # chunk-local end states: sum_j exp(seg_last - seg_j) dt_j B_j x_j
    decay = jnp.exp(seg_last - seg)                      # (b,nc,T,h)
    dtx = (dtc * decay)[..., None] * xc                  # (b,nc,T,h,p)
    Bh = jnp.repeat(Bc, hg, axis=3)                      # (b,nc,T,h,n)
    s_local = jnp.einsum("bcthn,bcthp->bchpn", Bh, dtx)  # (b,nc,h,p,n)

    # inter-chunk recurrence over nc
    chunk_decay = jnp.exp(seg_last[:, :, 0])             # (b,nc,h)
    if init_state is None:
        init_state = jnp.zeros((b, h, p, n), jnp.float32)

    def body(carry, inp):
        s_loc, dec = inp                                 # (b,h,p,n), (b,h)
        new = carry * dec[:, :, None, None] + s_loc
        return new, carry                                # emit state BEFORE

    final, prev_states = jax.lax.scan(
        body, init_state.astype(jnp.float32),
        (jnp.moveaxis(s_local, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)        # (b,nc,h,p,n)

    # off-diagonal: y_off[i] = exp(seg_i) * C_i . S_prev
    Ch = jnp.repeat(Cc, hg, axis=3)                      # (b,nc,T,h,n)
    y_off = jnp.einsum("bcthn,bchpn->bcthp", Ch, prev_states)
    y_off = y_off * jnp.exp(seg)[..., None]
    y = (y_diag + y_off).reshape(b, s, h, p)
    return y, final


def ssd_step(state, x, dt, A, B, C):
    """One recurrent step. state: (B,H,P,N); x: (B,H,P); dt: (B,H);
    B,C: (B,G,N). Returns (y (B,H,P), new_state)."""
    b, h, p, n = state.shape
    g = B.shape[1]
    hg = h // g
    Bh = jnp.repeat(B, hg, axis=1)                       # (b,h,n)
    Ch = jnp.repeat(C, hg, axis=1)
    da = jnp.exp(dt * A)                                 # (b,h)
    upd = (dt[..., None] * x)[..., None] * Bh[:, :, None, :]   # (b,h,p,n)
    new = state * da[..., None, None] + upd
    y = jnp.einsum("bhpn,bhn->bhp", new, Ch)
    return y, new


# ---------------------------------------------------------------------------
# Layer entry point
# ---------------------------------------------------------------------------


def mamba_layer(params, x, *, cfg: ModelConfig, mode: str,
                cache: Optional[Params] = None):
    """x: (B,S,D) -> (y (B,S,D), new_cache or None)."""
    s_cfg, d, di, nh, ng, ds, k, hp = _dims(cfg)
    b, s, _ = x.shape
    zxbcdt = jnp.einsum("bsd,de->bse", x, params["w_zxbcdt"])
    z, xbc, dt = _split_proj(zxbcdt, cfg)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])
    A = -jnp.exp(params["A_log"])

    if mode == "decode":
        assert cache is not None
        conv_out, conv_tail = _causal_conv(
            xbc, params["conv_w"], params["conv_b"], prev=cache["conv"])
        xs = conv_out[..., :di].reshape(b, nh, hp).astype(jnp.float32)
        Bm = conv_out[..., di:di + ng * ds].reshape(b, ng, ds)
        Cm = conv_out[..., di + ng * ds:].reshape(b, ng, ds)
        y, new_state = ssd_step(
            cache["ssm"].astype(jnp.float32), xs, dt[:, 0], A,
            Bm[:, :].astype(jnp.float32), Cm.astype(jnp.float32))
        y = y + params["ssm_D"][:, None] * xs
        y = y.reshape(b, 1, di)
        new_cache = {"ssm": new_state, "conv": conv_tail}
    else:
        prev_conv = cache["conv"] if cache is not None else None
        init_state = cache["ssm"] if cache is not None else None
        conv_out, conv_tail = _causal_conv(
            xbc, params["conv_w"], params["conv_b"], prev=prev_conv)
        xs = conv_out[..., :di].reshape(b, s, nh, hp)
        Bm = conv_out[..., di:di + ng * ds].reshape(b, s, ng, ds)
        Cm = conv_out[..., di + ng * ds:].reshape(b, s, ng, ds)
        chunk = min(s_cfg.chunk, s)
        pad = (-s) % chunk
        if pad:
            # zero-pad to a chunk multiple; dt=0 on padding makes the padded
            # steps identity transitions (no decay, no contribution)
            xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0), (0, 0)))
            Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
            Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
            dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        y, final = ssd_chunked(xs, dt, A, Bm, Cm, chunk,
                               init_state=init_state)
        if pad:
            y = y[:, :s]
            xs = xs[:, :s]
        y = y + params["ssm_D"][None, None, :, None] * \
            xs.astype(jnp.float32)
        y = y.reshape(b, s, di)
        new_cache = None
        if mode == "prefill":
            new_cache = {"ssm": final, "conv": conv_tail}

    y = y.astype(x.dtype) * jax.nn.silu(z)
    y = rmsnorm(y, params["ssm_norm"], cfg.norm_eps)
    return jnp.einsum("bse,ed->bsd", y, params["w_ssm_out"]), new_cache


def mamba_cache_shapes(cfg: ModelConfig, batch: int):
    s, d, di, nh, ng, ds, k, hp = _dims(cfg)
    return {"ssm": (batch, nh, hp, ds), "conv": (batch, k - 1,
                                                 di + 2 * ng * ds)}
