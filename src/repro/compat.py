"""JAX version-compat shims — the ONLY place allowed to touch
version-sensitive JAX symbols.

Policy (see README "Compat layer"): the JAX surface this repo needs has
drifted repeatedly across releases —

* ``jax.sharding.AxisType`` + ``jax.make_mesh(..., axis_types=...)``
  exist only on newer JAX; older releases have neither.
* ``jax.shard_map`` graduated from ``jax.experimental.shard_map``.
* Pallas-TPU compiler params were renamed
  ``TPUCompilerParams`` -> ``CompilerParams``.
* Memory-kind shardings (``memory_kind="pinned_host"``) are only
  constructible when the backend actually exposes that memory space.

Every other module imports the helpers below instead of reaching into
``jax.experimental`` / ``jax.sharding`` version-sensitive namespaces
directly; the grep lint in ``tests/test_compat.py`` fails the suite if
a drift-prone symbol appears outside this file.

Everything here resolves lazily (no module-level jax state) so
importing compat never touches jax device initialisation — the dry-run
sets ``xla_force_host_platform_device_count`` first.
"""
from __future__ import annotations

import functools
from typing import Any, Optional, Sequence, Tuple

import jax
import numpy as np


# ---------------------------------------------------------------------------
# Mesh construction (AxisType drift)
# ---------------------------------------------------------------------------


def axis_type_auto() -> Any:
    """``jax.sharding.AxisType.Auto`` where it exists, else ``None``."""
    at = getattr(jax.sharding, "AxisType", None)
    return getattr(at, "Auto", None) if at is not None else None


def make_mesh(shape: Sequence[int], axes: Sequence[str], *,
              devices: Optional[Sequence[Any]] = None):
    """``jax.make_mesh`` with Auto axis types when the installed JAX
    supports them, silently without when it does not (older JAX treats
    every axis as Auto anyway)."""
    shape = tuple(shape)
    axes = tuple(axes)
    auto = axis_type_auto()
    kw = {} if devices is None else {"devices": devices}
    if auto is not None and hasattr(jax, "make_mesh"):
        try:
            return jax.make_mesh(shape, axes,
                                 axis_types=(auto,) * len(axes), **kw)
        except TypeError:        # make_mesh predates axis_types kwarg
            pass
    if hasattr(jax, "make_mesh"):
        return jax.make_mesh(shape, axes, **kw)
    # pre-make_mesh JAX: build the Mesh by hand
    devs = np.array(devices if devices is not None
                    else jax.devices()[:int(np.prod(shape))])
    return jax.sharding.Mesh(devs.reshape(shape), axes)


def make_mesh_from_devices(devices: Sequence[Any], axes: Sequence[str]):
    """1-D (or reshaped) explicit-device mesh."""
    return jax.sharding.Mesh(np.array(devices), tuple(axes))


# ---------------------------------------------------------------------------
# shard_map (experimental -> top-level graduation)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=1)
def _resolve_shard_map():
    sm = getattr(jax, "shard_map", None)
    if sm is not None:
        return sm
    from jax.experimental import shard_map as _esm
    return _esm.shard_map


@functools.lru_cache(maxsize=1)
def _shard_map_params() -> frozenset:
    import inspect
    try:
        return frozenset(inspect.signature(_resolve_shard_map()).parameters)
    except (TypeError, ValueError):
        return frozenset()


def shard_map(f, *, mesh, in_specs, out_specs, check_rep=None, **kw):
    """Version-portable ``shard_map`` (keyword-only, both signatures).

    ``check_rep`` disables the static replication-rule check — required
    for bodies containing ``pallas_call`` (no replication rule is
    registered for it).  The kwarg itself drifted: older JAX spells it
    ``check_rep``, newer releases renamed it ``check_vma``; releases
    with neither simply don't check (the flag is dropped)."""
    if check_rep is not None:
        params = _shard_map_params()
        if "check_rep" in params:
            kw["check_rep"] = check_rep
        elif "check_vma" in params:
            kw["check_vma"] = check_rep
    return _resolve_shard_map()(f, mesh=mesh, in_specs=in_specs,
                                out_specs=out_specs, **kw)


@functools.lru_cache(maxsize=1)
def pallas_supported() -> bool:
    """Can Pallas kernels actually execute on this process's backend?

    True when a trivial ``pallas_call`` compiles and runs — compiled on
    TPU, interpret-mode elsewhere.  False on installs whose Pallas
    import or interpreter is broken/absent; callers (the spmd backend's
    rung activities) fall back to pure-jnp traffic loops, and the
    CurveDB ``execution`` provenance records which one ran."""
    try:
        import jax.numpy as jnp
        from jax.experimental import pallas as pl

        def _probe(x_ref, o_ref):
            o_ref[...] = x_ref[...] + 1.0

        out = pl.pallas_call(
            _probe,
            out_shape=jax.ShapeDtypeStruct((8, 128), jnp.float32),
            interpret=jax.default_backend() != "tpu",
        )(jnp.zeros((8, 128), jnp.float32))
        jax.block_until_ready(out)
        return True
    except Exception:
        return False


# ---------------------------------------------------------------------------
# In-dispatch timing probe (device-side rung clocks)
# ---------------------------------------------------------------------------


def _clock_parts(_dep=None):
    """Monotonic wall clock split into x32-safe int32 parts."""
    import time
    t = time.perf_counter_ns()
    return np.asarray([t // 1_000_000_000, t % 1_000_000_000], np.int32)


@functools.lru_cache(maxsize=1)
def _resolve_io_callback():
    """``jax.experimental.io_callback`` where it exists (it graduated
    from the old host_callback machinery); ``None`` on releases without
    it."""
    try:
        from jax.experimental import io_callback
        return io_callback
    except ImportError:
        return None


@functools.lru_cache(maxsize=1)
def device_clock_source() -> str:
    """Where :func:`device_clock` timestamps come from on this install.

    ``"device"`` when an on-accelerator cycle counter is exposed by the
    installed JAX (none is, on current public releases — when a TPU/GPU
    clock primitive lands it slots in here, ahead of the fallback);
    ``"callback"`` when the ``io_callback`` timestamp fallback is
    available; ``"none"`` when neither exists — callers (the fused spmd
    ladder) must then fall back to host wall-clock timing around whole
    dispatches."""
    if _resolve_io_callback() is not None:
        return "callback"
    return "none"


def device_clock(dep):
    """A ``(2,)``-int32 ``[seconds, nanoseconds]`` monotonic timestamp
    taken INSIDE the dispatch, data-dependent on ``dep``.

    The fused spmd ladder brackets every scanned rung sample with two of
    these, so per-rung elapsed time comes from in-dispatch deltas
    instead of host ``perf_counter`` around ``block_until_ready`` — no
    dispatch/interrupt jitter in the measured region, no extra host
    round-trips.  On installs without a timestamp source
    (``device_clock_source() == "none"``) this returns zeros; callers
    must check the source first.

    Consumers MUST thread the returned stamp's *value* into the work
    being timed (see the coordinator's exact-zero ``min(stamp, 0)``
    trick): the callback fallback fills its result buffer
    asynchronously, so a scheduling-only edge (``optimization_barrier``)
    does not make the measured work wait for the stamp."""
    import jax.numpy as jnp
    ioc = _resolve_io_callback()
    if ioc is None:
        return jnp.zeros((2,), jnp.int32)
    return ioc(_clock_parts, jax.ShapeDtypeStruct((2,), jnp.int32),
               dep, ordered=False)


# ---------------------------------------------------------------------------
# AOT compilation + persistent compile cache (jit staging / config drift)
# ---------------------------------------------------------------------------


def aot_trace(jitted, *args):
    """``jitted.trace(*args)`` where the installed JAX exposes the AOT
    ``Traced`` stage of the trace -> lower -> compile pipeline; ``None``
    on releases without it.  One trace then serves BOTH the structural
    fence check (via ``traced.jaxpr``) and :func:`aot_compile` — without
    it the spmd program builder traces every program twice (once in
    ``make_jaxpr`` for the fence walk, once again at first dispatch)."""
    trace = getattr(jitted, "trace", None)
    if trace is None:
        return None
    try:
        traced = trace(*args)
    except Exception:
        return None
    return traced if hasattr(traced, "jaxpr") else None


def aot_compile(jitted, *args, traced=None):
    """Ahead-of-time ``jit(...).lower(...).compile()``: ONE compiled
    executable per program signature, built at a controlled point
    instead of inside the first timed dispatch (reusing a ``traced``
    stage from :func:`aot_trace` when given, so the program is traced
    exactly once end to end).  With :func:`persistent_cache` enabled,
    ``compile()`` consults the on-disk cache, so repeated processes
    skip the XLA compile wall for cacheable programs.  Returns ``None``
    when the installed JAX cannot AOT-compile this program — callers
    fall back to dispatch-triggered compilation and must record the
    degradation (the CurveDB ``execution["aot"]`` provenance)."""
    try:
        if traced is not None:
            return traced.lower().compile()
        lower = getattr(jitted, "lower", None)
        if lower is None:
            return None
        return lower(*args).compile()
    except Exception:
        return None


def persistent_cache(cache_dir: str) -> bool:
    """Enable JAX's persistent compilation cache at ``cache_dir`` and
    return whether it took effect.

    SCOPE: the cache is PROCESS-GLOBAL JAX configuration, not
    per-caller state — once enabled it serves (and is written by)
    every compile in the process, and a later call with a different
    directory re-points the whole process.  Callers advertising an
    opt-in (``CoreCoordinator(compile_cache_dir=...)``) must document
    that the opt-in escapes the instance; pass a directory that
    outlives the process's compiles.

    The config spelling drifted (``jax_compilation_cache_dir`` config
    key on current releases, ``compilation_cache.set_cache_dir`` on
    older ones); the write-threshold knobs
    (``jax_persistent_cache_min_*``) are best-effort — absent knobs
    keep that release's defaults.  Honesty note: XLA refuses to persist
    programs containing HOST CALLBACKS, so on installs where
    :func:`device_clock_source` is ``"callback"`` the device-timed
    fused/batched ladder programs recompile per process — the cache
    still eliminates the compile wall for the host-timed rung programs
    and the interpret-path measured passes, and a real accelerator
    clock primitive (no callback) would make the fused programs
    cacheable too."""
    try:
        jax.config.update("jax_compilation_cache_dir", cache_dir)
    except Exception:
        try:
            from jax.experimental.compilation_cache import (
                compilation_cache as _cc)
            _cc.set_cache_dir(cache_dir)
        except Exception:
            return False
    # the cache module memoizes a "disabled" verdict if anything was
    # compiled before the dir was set (e.g. compat probes); reset it so
    # the next compilation re-initializes against the new directory
    try:
        from jax.experimental.compilation_cache import (
            compilation_cache as _cc)
        _cc.reset_cache()
    except Exception:
        pass
    # cache every program, however small/fast to compile: the spmd
    # sweeps are dominated by many medium-sized programs that sit
    # below the default write thresholds
    for opt, val in (("jax_persistent_cache_min_entry_size_bytes", -1),
                     ("jax_persistent_cache_min_compile_time_secs", 0.0)):
        try:
            jax.config.update(opt, val)
        except Exception:
            pass
    return True


# ---------------------------------------------------------------------------
# Input buffer donation (per-backend availability)
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=1)
def donation_supported() -> bool:
    """Does this process's backend implement input buffer donation?

    Probed by compiling a trivial donated program and checking that JAX
    did not warn the donation away (platforms without donation keep the
    program correct but ignore ``donate_argnums``).  The fused spmd
    ladder donates its cached rung operands so repeated dispatches alias
    buffers in place instead of copying."""
    import warnings
    import jax.numpy as jnp
    try:
        x = jnp.ones((8,), jnp.float32)
        with warnings.catch_warnings(record=True) as w:
            warnings.simplefilter("always")
            out = jax.jit(lambda v: v + 1.0, donate_argnums=0)(x)
            jax.block_until_ready(out)
        return not any("donat" in str(m.message).lower() for m in w)
    except Exception:
        return False


def optimization_barrier(x):
    """``jax.lax.optimization_barrier`` where it exists (it moved into
    ``jax.lax`` from ad_checkpoint internals); identity on releases
    without it.  Used to pin the SPMD measured region behind the start
    barrier: threading the barrier psum through this op gives the
    measured activity a dataflow dependency XLA cannot hoist across."""
    fn = getattr(jax.lax, "optimization_barrier", None)
    if fn is None:
        return x
    return fn(x)


def psum_grouped(x, axis, groups=None):
    """``jax.lax.psum`` over disjoint index groups of one mesh axis —
    the grouped-collective spelling behind engine-subset width-packing
    (each packed ladder's psum sandwich reduces over ITS engine subset
    only).  ``groups`` is a tuple of index tuples that must partition
    the axis (e.g. ``((0, 1), (2, 3))`` on a 4-engine mesh); ``None``
    or empty means a plain global all-reduce.

    The keyword has drifted before (``axis_index_groups`` was once
    positional-adjacent to ``axis_name`` and its validation rules vary
    across releases), so the raw spelling is confined to this shim
    (the grep lint in tests/test_compat.py rejects it elsewhere).  On
    a release that rejects the keyword this degrades to a GLOBAL psum:
    numerically safe (it is a strictly stronger barrier) but it breaks
    subset isolation — the packed fence check sees the ungrouped psum
    in the jaxpr and honestly reports the program unfenced."""
    if not groups:
        return jax.lax.psum(x, axis)
    try:
        return jax.lax.psum(
            x, axis,
            axis_index_groups=tuple(tuple(g) for g in groups))
    except TypeError:
        return jax.lax.psum(x, axis)


def pvary(x, axes):
    """``jax.lax.pvary`` where it exists (newer shard_map replication
    typing); identity on older JAX, where values are device-varying by
    default and no marker is needed."""
    fn = getattr(jax.lax, "pvary", None)
    if fn is None:
        return x
    return fn(x, axes)


# ---------------------------------------------------------------------------
# Pallas TPU compiler params (TPUCompilerParams -> CompilerParams rename)
# ---------------------------------------------------------------------------


def tpu_compiler_params(**kw) -> Any:
    """Construct Pallas-TPU compiler params under either name.

    Returns ``None`` when neither class exists (pure-interpret installs);
    ``pallas_call`` accepts ``compiler_params=None``.
    """
    from jax.experimental.pallas import tpu as pltpu
    cls = getattr(pltpu, "CompilerParams", None) \
        or getattr(pltpu, "TPUCompilerParams", None)
    if cls is None:
        return None
    try:
        return cls(**kw)
    except TypeError:
        # field drift inside the params class: drop unknown kwargs
        import inspect
        ok = set(inspect.signature(cls).parameters)
        return cls(**{k: v for k, v in kw.items() if k in ok})


# ---------------------------------------------------------------------------
# Compiled-program cost analysis (list-of-dicts -> dict drift)
# ---------------------------------------------------------------------------


def cost_analysis(compiled) -> dict:
    """``compiled.cost_analysis()`` as a flat dict on every JAX version
    (older releases return a one-element list of per-program dicts)."""
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {}
    if ca is None:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca)


# ---------------------------------------------------------------------------
# Memory-kind shardings (HBM vs pinned-host placement)
# ---------------------------------------------------------------------------


def device_memory_kinds(device) -> Tuple[str, ...]:
    try:
        return tuple(m.kind for m in device.addressable_memories())
    except Exception:
        return ()


def single_device_sharding(device, memory_kind: Optional[str] = None):
    """SingleDeviceSharding with ``memory_kind`` when the device can
    address it, plain default-memory sharding otherwise (CPU containers
    model host placement; they cannot materialise it)."""
    if memory_kind is not None and memory_kind in device_memory_kinds(device):
        try:
            return jax.sharding.SingleDeviceSharding(
                device, memory_kind=memory_kind)
        except (TypeError, ValueError, RuntimeError):
            pass
    return jax.sharding.SingleDeviceSharding(device)


def named_sharding(mesh, spec, memory_kind: Optional[str] = None):
    """NamedSharding with the same graceful memory-kind degradation."""
    if memory_kind is not None:
        kinds = device_memory_kinds(mesh.devices.flat[0])
        if memory_kind in kinds:
            try:
                return jax.sharding.NamedSharding(
                    mesh, spec, memory_kind=memory_kind)
            except (TypeError, ValueError, RuntimeError):
                pass
    return jax.sharding.NamedSharding(mesh, spec)
