"""Flash attention (online-softmax, causal + sliding-window), Pallas TPU.

The model-side perf-critical kernel: blockwise attention that never
materialises the (Sq, Sk) score matrix in HBM.  Supports GQA natively via
the KV-head index map (no repeated-KV materialisation) and gemma-style
sliding windows via block skipping — an out-of-window KV block is never
DMA'd at all, which is what makes local-attention layers O(S·W) in both
FLOPs *and* bytes.

Layout: q (B, H, Sq, D); k, v (B, KVH, Sk, D); H % KVH == 0.
Grid (B, H, nq, nk), nk innermost/sequential; m/l/acc live in VMEM
scratch and persist across the nk loop (standard TPU flash schedule).
Accumulation is f32 regardless of input dtype.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro import compat

NEG_INF = -1e30
LANE = 128


def _flash_body(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                sm_scale: float, block_q: int, block_k: int, n_k: int,
                causal: bool, window: int, sk_valid: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # --- static-ish block skip predicates (computed on grid indices) -----
    run = jnp.bool_(True)
    if causal:
        # lowest kv pos in this block must not exceed highest q pos
        run = run & (ik * block_k <= iq * block_q + block_q - 1)
    if window:
        # highest kv pos must be within the window of the lowest q pos
        run = run & (ik * block_k + block_k - 1 > iq * block_q - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (bq, d)
        k = k_ref[0, 0].astype(jnp.float32)            # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * sm_scale

        q_pos = iq * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        k_pos = ik * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        mask = jnp.bool_(jnp.ones((block_q, block_k), jnp.bool_))
        if causal:
            mask = mask & (k_pos <= q_pos)
        if window:
            mask = mask & (k_pos > q_pos - window)
        if sk_valid % block_k:                 # padded kv tail block
            mask = mask & (k_pos < sk_valid)
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, :1]                          # (bq, 1)
        m_cur = jnp.max(s, axis=-1, keepdims=True)     # (bq, 1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)                # (bq, 1)
        p = jnp.exp(s - m_new)                         # (bq, bk)
        p = jnp.where(mask, p, 0.0)

        l_ref[...] = jnp.broadcast_to(
            l_ref[:, :1] * alpha + jnp.sum(p, -1, keepdims=True),
            l_ref.shape)
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        v = v_ref[0, 0].astype(jnp.float32)            # (bk, d)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ik == n_k - 1)
    def _finalize():
        l = l_ref[:, :1]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_ref[...] / safe).astype(o_ref.dtype)


def flash_attention(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
    causal: bool = True, window: int = 0,
    sm_scale: Optional[float] = None,
    block_q: int = 128, block_k: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """q: (B,H,Sq,D); k,v: (B,KVH,Sk,D) -> (B,H,Sq,D).

    Sequences need not divide the block shape: q/k/v are zero-padded up
    to the block grid and the padded kv tail is masked inside the kernel
    (an out-of-range score block contributes exp(-inf) = 0), so the
    result is bit-for-bit independent of the tiling.
    """
    b, h, sq, d = q.shape
    _, kvh, sk, _ = k.shape
    assert h % kvh == 0, (h, kvh)
    block_q = min(block_q, sq)
    block_k = min(block_k, sk)
    pad_q = -sq % block_q
    pad_k = -sk % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    sq_p, sk_p = sq + pad_q, sk + pad_k
    n_q, n_k = sq_p // block_q, sk_p // block_k
    scale = sm_scale if sm_scale is not None else d ** -0.5

    grid = (b, h, n_q, n_k)
    body = functools.partial(
        _flash_body, sm_scale=scale, block_q=block_q, block_k=block_k,
        n_k=n_k, causal=causal, window=window, sk_valid=sk)

    out = pl.pallas_call(
        body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda bb, hh, qq, kk: (bb, hh, qq, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bb, hh, qq, kk, kvh=kvh, h=h:
                         (bb, hh * kvh // h, kk, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda bb, hh, qq, kk, kvh=kvh, h=h:
                         (bb, hh * kvh // h, kk, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda bb, hh, qq, kk: (bb, hh, qq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, LANE), jnp.float32),   # m
            pltpu.VMEM((block_q, LANE), jnp.float32),   # l
            pltpu.VMEM((block_q, d), jnp.float32),      # acc
        ],
        compiler_params=compat.tpu_compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
    return out[:, :, :sq] if pad_q else out
