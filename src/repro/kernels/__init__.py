"""Pallas TPU microbenchmark + model kernels.

stream.py           sequential bandwidth (r/w/s/x/y access strategies)
chase.py            data-dependent pointer-chase latency (l/m)
compute_probe.py    MXU busy loop (memory-idle activity)
flash_attention.py  online-softmax blockwise attention (causal + window)
ops.py              jit'd wrappers (interpret=True off-TPU)
ref.py              pure-jnp oracles
"""
from repro.kernels import ops, ref  # noqa: F401
