"""jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True off-TPU (this container) and False on real
hardware, so the same call sites work in both environments.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import chase as _chase
from repro.kernels import compute_probe as _probe
from repro.kernels import flash_attention as _flash
from repro.kernels import stream as _stream


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interp(override: Optional[bool]) -> bool:
    return (not on_tpu()) if override is None else override


# --- stream ------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def stream_read(x, *, block_rows: int = 512, interpret: Optional[bool] = None):
    return _stream.read_hbm(x, block_rows=block_rows,
                            interpret=_interp(interpret))


@functools.partial(jax.jit,
                   static_argnames=("rows", "block_rows", "interpret"))
def stream_write(*, rows: int, block_rows: int = 512,
                 interpret: Optional[bool] = None):
    return _stream.write_hbm(rows, block_rows=block_rows,
                             interpret=_interp(interpret))


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def stream_rmw(x, *, block_rows: int = 512,
               interpret: Optional[bool] = None):
    return _stream.rmw_hbm(x, block_rows=block_rows,
                           interpret=_interp(interpret))


@functools.partial(jax.jit,
                   static_argnames=("rows", "block_rows", "interpret"))
def stream_write_seeded(seed, *, rows: int, block_rows: int = 512,
                        interpret: Optional[bool] = None):
    return _stream.write_hbm_seeded(seed, rows, block_rows=block_rows,
                                    interpret=_interp(interpret))


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def stream_copy(x, *, block_rows: int = 512,
                interpret: Optional[bool] = None):
    return _stream.copy_hbm(x, block_rows=block_rows,
                            interpret=_interp(interpret))


@functools.partial(jax.jit,
                   static_argnames=("scalar", "block_rows", "interpret"))
def stream_triad(b, c, *, scalar: float = 3.0, block_rows: int = 512,
                 interpret: Optional[bool] = None):
    return _stream.triad_hbm(b, c, scalar=scalar, block_rows=block_rows,
                             interpret=_interp(interpret))


@functools.partial(jax.jit, static_argnames=("read_fraction",
                                             "block_rows", "interpret"))
def stream_mixed(x, *, read_fraction: float, block_rows: int = 512,
                 interpret: Optional[bool] = None):
    return _stream.mixed_hbm(x, read_fraction=read_fraction,
                             block_rows=block_rows,
                             interpret=_interp(interpret))


@functools.partial(jax.jit, static_argnames=("repeats", "interpret"))
def vmem_read(x, *, repeats: int = 16, interpret: Optional[bool] = None):
    return _stream.read_vmem(x, repeats=repeats,
                             interpret=_interp(interpret))


@functools.partial(jax.jit,
                   static_argnames=("rows", "repeats", "interpret"))
def vmem_write(*, rows: int, repeats: int = 16,
               interpret: Optional[bool] = None):
    return _stream.write_vmem(rows, repeats=repeats,
                              interpret=_interp(interpret))


# --- chase -------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("n_steps", "interpret"))
def chase_vmem(buf, *, n_steps: int, interpret: Optional[bool] = None):
    return _chase.chase_vmem(buf, n_steps=n_steps,
                             interpret=_interp(interpret))


@functools.partial(jax.jit, static_argnames=("n_steps", "interpret"))
def chase_hbm(buf, *, n_steps: int, interpret: Optional[bool] = None):
    return _chase.chase_hbm(buf, n_steps=n_steps,
                            interpret=_interp(interpret))


make_chain = _chase.make_chain
chain_buffer = _chase.chain_buffer
make_strided_chain = _chase.make_strided_chain
strided_chain_buffer = _chase.strided_chain_buffer


# --- compute probe -------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("iters", "interpret"))
def mxu_probe(a, *, iters: int = 64, interpret: Optional[bool] = None):
    return _probe.mxu_probe(a, iters=iters, interpret=_interp(interpret))


# --- flash attention -----------------------------------------------------------


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "sm_scale", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    sm_scale: Optional[float] = None, block_q: int = 128,
                    block_k: int = 128, interpret: Optional[bool] = None):
    return _flash.flash_attention(
        q, k, v, causal=causal, window=window, sm_scale=sm_scale,
        block_q=block_q, block_k=block_k, interpret=_interp(interpret))
