"""Pure-jnp oracles for every Pallas kernel (allclose targets in tests)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


# --- stream ----------------------------------------------------------------


def read_ref(x: jnp.ndarray) -> jnp.ndarray:
    return jnp.sum(x, dtype=jnp.float32)


def write_ref(shape_rows: int, value: float = 1.0) -> jnp.ndarray:
    return jnp.full((shape_rows, 128), value, jnp.float32)


def rmw_ref(x: jnp.ndarray) -> jnp.ndarray:
    return x + 1.0


def copy_ref(x: jnp.ndarray) -> jnp.ndarray:
    return x


def triad_ref(b: jnp.ndarray, c: jnp.ndarray, scalar: float = 3.0):
    return b + scalar * c


def read_vmem_ref(x: jnp.ndarray, repeats: int) -> jnp.ndarray:
    return jnp.sum(x, dtype=jnp.float32) * repeats


def write_vmem_ref(shape_rows: int, repeats: int) -> jnp.ndarray:
    return jnp.full((shape_rows, 128), float(repeats - 1), jnp.float32)


# --- chase -----------------------------------------------------------------


def chase_ref(buf: np.ndarray, n_steps: int) -> int:
    idx = 0
    nxt = np.asarray(buf)[:, 0]
    for _ in range(n_steps):
        idx = int(nxt[idx])
    return idx


# --- compute probe ----------------------------------------------------------


def mxu_probe_ref(a: jnp.ndarray, iters: int) -> jnp.ndarray:
    out = a.astype(jnp.float32)
    for _ in range(iters):
        out = out @ a.astype(jnp.float32)
    return out


# --- flash attention ---------------------------------------------------------


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  sm_scale: Optional[float] = None) -> jnp.ndarray:
    """q: (B,H,Sq,D); k,v: (B,KVH,Sk,D) -> (B,H,Sq,D). Dense oracle."""
    b, h, sq, d = q.shape
    _, kvh, sk, _ = k.shape
    g = h // kvh
    scale = sm_scale if sm_scale is not None else d ** -0.5
    k = jnp.repeat(k, g, axis=1)
    v = jnp.repeat(v, g, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    q_pos = jnp.arange(sq)[:, None]
    k_pos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask = mask & (k_pos <= q_pos)
    if window:
        mask = mask & (k_pos > q_pos - window)
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p,
                      v.astype(jnp.float32)).astype(q.dtype)
