"""Data-dependent pointer-chase latency kernels (the paper's l / m).

MEMSCOPE measures round-trip latency by ensuring exactly one outstanding
memory transaction: the next address is only known once the previous load
returns.  The buffer is initialised as a single permutation *cycle*
(Sattolo's algorithm — the TPU-native equivalent of the paper's
Appendix-A swap-based shuffle: full coverage, no repeats, unprefetchable).

Two TPU-native variants:

* ``chase_vmem`` (strategy ``l``) — the chain lives in a VMEM-resident
  block; an inner ``fori_loop`` performs truly dependent loads
  (``idx = buf[idx]``).  Measures on-chip (VMEM) load-to-use latency.
* ``chase_hbm``  (strategy ``m``) — the chain lives in HBM
  (``memory_space=ANY``); every step issues a single-line DMA
  HBM->VMEM, waits for it, and reads the next index from the landed
  line.  One outstanding transaction by construction — this is the
  ``dc civac`` non-cacheable chase, adapted to a software-managed
  memory hierarchy.

Line layout: (n_lines, 128) int32 — one 512-byte lane-row per "cache
line"; element [i, 0] holds the successor of line i.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANE = 128


# ---------------------------------------------------------------------------
# Chain initialisation (the paper's Fig. 16, steps 1-3)
# ---------------------------------------------------------------------------


def make_chain(n_lines: int, seed: int = 0) -> np.ndarray:
    """Sattolo cyclic permutation: following next[i] from 0 visits every
    line exactly once before returning to 0."""
    rng = np.random.default_rng(seed)
    p = np.arange(n_lines)
    for i in range(n_lines - 1, 0, -1):
        j = rng.integers(0, i)
        p[i], p[j] = p[j], p[i]
    return p.astype(np.int32)


def chain_buffer(n_lines: int, seed: int = 0) -> np.ndarray:
    """(n_lines, 128) int32 buffer with the successor in lane 0."""
    buf = np.zeros((n_lines, LANE), np.int32)
    buf[:, 0] = make_chain(n_lines, seed)
    return buf


def make_strided_chain(n_lines: int, stride: int) -> np.ndarray:
    """Deterministic strided cycle: next[i] = (i + stride') mod n with
    stride' the smallest value >= stride coprime to n, so the walk still
    visits every line exactly once.  Unlike the Sattolo shuffle the hop
    distance is CONSTANT — the strided-chase traffic shape: predictable
    distance, no spatial locality beyond the stride."""
    if n_lines == 1:
        return np.zeros(1, np.int32)
    s = max(1, stride) % n_lines or 1
    while math.gcd(s, n_lines) != 1:
        s += 1
        if s >= n_lines:
            s = 1
            break
    return ((np.arange(n_lines) + s) % n_lines).astype(np.int32)


def strided_chain_buffer(n_lines: int, stride: int) -> np.ndarray:
    """(n_lines, 128) int32 strided-cycle buffer (successor in lane 0)."""
    buf = np.zeros((n_lines, LANE), np.int32)
    buf[:, 0] = make_strided_chain(n_lines, stride)
    return buf


# ---------------------------------------------------------------------------
# VMEM chase (l)
# ---------------------------------------------------------------------------


def _chase_vmem_body(x_ref, o_ref, *, n_steps: int):
    def step(_, idx):
        return x_ref[idx, 0]

    o_ref[0, 0] = jax.lax.fori_loop(0, n_steps, step, jnp.int32(0))


def chase_vmem(buf: jnp.ndarray, *, n_steps: int,
               interpret: bool = False) -> jnp.ndarray:
    """buf: (n_lines, 128) int32, VMEM-resident. Returns the final index
    (data-dependent on every intermediate load)."""
    return pl.pallas_call(
        functools.partial(_chase_vmem_body, n_steps=n_steps),
        in_specs=[pl.BlockSpec(buf.shape, lambda: (0, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.int32),
        interpret=interpret,
    )(buf)[0, 0]


# ---------------------------------------------------------------------------
# HBM chase (m): one line DMA'd per dependent step
# ---------------------------------------------------------------------------


def _chase_hbm_body(x_hbm_ref, o_ref, line_ref, sem, *, n_steps: int):
    def step(_, idx):
        cp = pltpu.make_async_copy(
            x_hbm_ref.at[pl.ds(idx, 1)], line_ref, sem)
        cp.start()
        cp.wait()
        return line_ref[0, 0]

    o_ref[0, 0] = jax.lax.fori_loop(0, n_steps, step, jnp.int32(0))


def chase_hbm(buf: jnp.ndarray, *, n_steps: int,
              interpret: bool = False) -> jnp.ndarray:
    """buf: (n_lines, 128) int32 staying in HBM; exactly one outstanding
    single-line DMA at any time."""
    return pl.pallas_call(
        functools.partial(_chase_hbm_body, n_steps=n_steps),
        in_specs=[pl.BlockSpec(memory_space=pl.ANY)],
        out_specs=pl.BlockSpec((1, 1), lambda: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.int32),
        scratch_shapes=[pltpu.VMEM((1, LANE), jnp.int32),
                        pltpu.SemaphoreType.DMA],
        interpret=interpret,
    )(buf)[0, 0]
