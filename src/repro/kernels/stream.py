"""Sequential bandwidth microbenchmark kernels (the paper's r/w/s/x/y).

TPU adaptation of MEMSCOPE's assembly bandwidth test benches.  On the
ZCU102 the distinction is cacheable vs. non-cacheable *instructions*; on a
TPU the "cache" is VMEM (software-managed), so the distinction becomes a
**BlockSpec choice**:

* ``*_hbm``  — grid over HBM blocks, each block DMA'd into VMEM exactly
  once (the non-cacheable analog: every byte travels HBM<->VMEM).
* ``*_vmem`` — a single VMEM-resident block iterated ``repeats`` times by
  an inner ``fori_loop`` (the cacheable analog: traffic stays on-chip).

Ops:
  read   (r/s)  sum-reduce each block (result returned so XLA can't DCE).
  write  (w/x)  write a constant to each block; with write-allocate
                semantics the destination is also an *input* (aliased), so
                the line is read before written — MEMSCOPE's ``x``.
  stream (y)    pure write, destination never read — MEMSCOPE's ``dc zva``
                write-streaming (write-no-allocate).
  copy / triad  STREAM-style composites used by the validation benchmark.

All kernels use (block_rows, 128) f32 blocks (lane-aligned for the VPU).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 128
DEFAULT_BLOCK_ROWS = 512  # 512*128*4B = 256 KiB per block


def _grid_blocks(n_rows: int, block_rows: int) -> int:
    assert n_rows % block_rows == 0, (n_rows, block_rows)
    return n_rows // block_rows


# ---------------------------------------------------------------------------
# Kernel bodies
# ---------------------------------------------------------------------------


def _read_body(x_ref, acc_ref):
    acc_ref[0, 0] = jnp.sum(x_ref[...], dtype=jnp.float32)


def _write_body(o_ref, *, value: float):
    o_ref[...] = jnp.full_like(o_ref, value)


def _write_seeded_body(seed_ref, o_ref, *, value: float):
    # the stored value depends on the (1,1) seed operand, so the store
    # traffic carries a dataflow edge from whatever produced the seed —
    # one extra scalar read total, still a pure write stream per line
    o_ref[...] = jnp.full_like(o_ref, value) + seed_ref[0, 0]


def _rmw_body(x_ref, o_ref):
    # write-allocate analog: the line is read, modified, written back
    o_ref[...] = x_ref[...] + 1.0


def _copy_body(x_ref, o_ref):
    o_ref[...] = x_ref[...]


def _triad_body(b_ref, c_ref, o_ref, *, scalar: float):
    o_ref[...] = b_ref[...] + scalar * c_ref[...]


def _read_vmem_body(x_ref, acc_ref, *, repeats: int):
    def step(i, acc):
        # rotate a tiny offset so the loop is not hoisted; all traffic VMEM
        return acc + jnp.sum(x_ref[...], dtype=jnp.float32) + i * 0.0

    acc_ref[0, 0] = jax.lax.fori_loop(0, repeats, step, jnp.float32(0.0))


def _write_vmem_body(o_ref, *, repeats: int):
    def step(i, _):
        o_ref[...] = jnp.full_like(o_ref, i.astype(jnp.float32))
        return 0

    jax.lax.fori_loop(0, repeats, step, 0)


# ---------------------------------------------------------------------------
# pallas_call wrappers (HBM-streaming variants: grid over blocks)
# ---------------------------------------------------------------------------


def read_hbm(x: jnp.ndarray, *, block_rows: int = DEFAULT_BLOCK_ROWS,
             interpret: bool = False) -> jnp.ndarray:
    """Sum x by streaming every block through VMEM once. x: (R, 128) f32."""
    n = _grid_blocks(x.shape[0], block_rows)
    out = pl.pallas_call(
        _read_body,
        grid=(n,),
        in_specs=[pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), jnp.float32),
        interpret=interpret,
    )(x)
    return jnp.sum(out)


def write_hbm(shape_rows: int, *, value: float = 1.0,
              block_rows: int = DEFAULT_BLOCK_ROWS,
              interpret: bool = False) -> jnp.ndarray:
    """Write-streaming (y): pure stores, destination never read."""
    n = _grid_blocks(shape_rows, block_rows)
    return pl.pallas_call(
        functools.partial(_write_body, value=value),
        grid=(n,),
        in_specs=[],
        out_specs=pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((shape_rows, LANE), jnp.float32),
        interpret=interpret,
    )()


def write_hbm_seeded(seed: jnp.ndarray, shape_rows: int, *,
                     value: float = 1.0,
                     block_rows: int = DEFAULT_BLOCK_ROWS,
                     interpret: bool = False) -> jnp.ndarray:
    """Write-streaming (y) with a dataflow anchor: identical store
    traffic to :func:`write_hbm`, but the stored value depends on the
    (1, 1) f32 ``seed`` operand.  The SPMD backend uses this so a pure
    write activity cannot be hoisted above the rung's start barrier —
    ``write_hbm`` takes no operands at all, which leaves the measured
    region structurally unfenced (see ``measured_region_is_fenced``)."""
    n = _grid_blocks(shape_rows, block_rows)
    return pl.pallas_call(
        functools.partial(_write_seeded_body, value=value),
        grid=(n,),
        in_specs=[pl.BlockSpec((1, 1), lambda i: (0, 0))],
        out_specs=pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((shape_rows, LANE), jnp.float32),
        interpret=interpret,
    )(seed)


def rmw_hbm(x: jnp.ndarray, *, block_rows: int = DEFAULT_BLOCK_ROWS,
            interpret: bool = False) -> jnp.ndarray:
    """Write-allocate (x): every line read, modified, written back."""
    n = _grid_blocks(x.shape[0], block_rows)
    return pl.pallas_call(
        _rmw_body,
        grid=(n,),
        in_specs=[pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x)


def copy_hbm(x: jnp.ndarray, *, block_rows: int = DEFAULT_BLOCK_ROWS,
             interpret: bool = False) -> jnp.ndarray:
    n = _grid_blocks(x.shape[0], block_rows)
    return pl.pallas_call(
        _copy_body,
        grid=(n,),
        in_specs=[pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        interpret=interpret,
    )(x)


def triad_hbm(b: jnp.ndarray, c: jnp.ndarray, *, scalar: float = 3.0,
              block_rows: int = DEFAULT_BLOCK_ROWS,
              interpret: bool = False) -> jnp.ndarray:
    n = _grid_blocks(b.shape[0], block_rows)
    spec = pl.BlockSpec((block_rows, LANE), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_triad_body, scalar=scalar),
        grid=(n,),
        in_specs=[spec, spec],
        out_specs=pl.BlockSpec((block_rows, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(b.shape, b.dtype),
        interpret=interpret,
    )(b, c)


def mixed_hbm(x: jnp.ndarray, *, read_fraction: float,
              value: float = 1.0, block_rows: int = DEFAULT_BLOCK_ROWS,
              interpret: bool = False,
              seed: Optional[jnp.ndarray] = None):
    """Mixed read/write stream: ``read_fraction`` of the blocks are
    sum-reduced (pure read traffic), the rest are written (pure store
    traffic) — nothing else touches memory, so the realized read:write
    line ratio IS the configured one.  Interleave order is irrelevant
    to a bandwidth mix, so the split is by row range.

    Returns (read_sum, written): read_sum keeps the read traffic live
    under DCE; written is the store destination.

    The ratio is realized at whole-block granularity; when the buffer
    holds few blocks at the requested block size, the block size is
    reduced (to the largest row-count divisor giving >= 8 blocks) so a
    small buffer cannot silently degenerate to a pure read or write.

    ``seed`` (optional (1, 1) f32): route the write half through
    :func:`write_hbm_seeded` so the store traffic carries a dataflow
    edge from the seed — required when the mix runs inside a fenced
    SPMD measured region (a no-operand write kernel could be hoisted
    above the start barrier).
    """
    assert 0.0 <= read_fraction <= 1.0
    rows = x.shape[0]
    if 0.0 < read_fraction < 1.0 and rows // block_rows < 8:
        block_rows = next(b for b in range(max(1, rows // 8), 0, -1)
                          if rows % b == 0)
    n = _grid_blocks(rows, block_rows)
    n_r = max(0, min(n, int(round(n * read_fraction))))
    if 0.0 < read_fraction < 1.0 and n >= 2:
        # an extreme but genuine mix keeps >= 1 block of each kind
        n_r = max(1, min(n - 1, n_r))
    n_w = n - n_r
    acc = jnp.float32(0.0)
    out = jnp.zeros((0, LANE), jnp.float32)
    if n_r:
        acc = read_hbm(x[:n_r * block_rows], block_rows=block_rows,
                       interpret=interpret)
    if n_w:
        if seed is not None:
            out = write_hbm_seeded(seed, n_w * block_rows, value=value,
                                   block_rows=block_rows,
                                   interpret=interpret)
        else:
            out = write_hbm(n_w * block_rows, value=value,
                            block_rows=block_rows, interpret=interpret)
    return acc, out


# ---------------------------------------------------------------------------
# VMEM-resident variants (cacheable analog)
# ---------------------------------------------------------------------------


def read_vmem(x: jnp.ndarray, *, repeats: int = 16,
              interpret: bool = False) -> jnp.ndarray:
    """Re-read a VMEM-resident buffer `repeats` times (one DMA in)."""
    return pl.pallas_call(
        functools.partial(_read_vmem_body, repeats=repeats),
        in_specs=[pl.BlockSpec(x.shape, lambda: (0, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=interpret,
    )(x)[0, 0]


def write_vmem(shape_rows: int, *, repeats: int = 16,
               interpret: bool = False) -> jnp.ndarray:
    """Re-write a VMEM-resident buffer `repeats` times (one DMA out)."""
    return pl.pallas_call(
        functools.partial(_write_vmem_body, repeats=repeats),
        in_specs=[],
        out_specs=pl.BlockSpec((shape_rows, LANE), lambda: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((shape_rows, LANE), jnp.float32),
        interpret=interpret,
    )()
