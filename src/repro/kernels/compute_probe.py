"""MXU busy-loop — the paper's "memory-idle" activity, TPU-native.

MEMSCOPE keeps non-stressor cores *memory-idle* with a CPU-bound busy
loop so they contribute zero memory traffic while still being online.
The TPU analog: a chain of (128, 128) matmuls on a VMEM-resident operand.
After the single initial DMA the kernel generates no HBM traffic at all —
it just occupies the MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

MXU = 128


def _probe_body(a_ref, o_ref, *, iters: int):
    def step(i, acc):
        return jnp.dot(acc, a_ref[...],
                       preferred_element_type=jnp.float32)

    o_ref[...] = jax.lax.fori_loop(
        0, iters, step, a_ref[...].astype(jnp.float32))


def mxu_probe(a: jnp.ndarray, *, iters: int = 64,
              interpret: bool = False) -> jnp.ndarray:
    """a: (128, 128) f32. Returns a^(iters+1) — MXU-bound, memory-idle."""
    return pl.pallas_call(
        functools.partial(_probe_body, iters=iters),
        in_specs=[pl.BlockSpec((MXU, MXU), lambda: (0, 0))],
        out_specs=pl.BlockSpec((MXU, MXU), lambda: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((MXU, MXU), jnp.float32),
        interpret=interpret,
    )(a)
