"""Fault tolerance: resilient train loop, straggler monitor, elastic re-mesh.

Designed for the 1000-node regime where *something is always failing*:

* :class:`ResilientLoop` — wraps the train step; on a step failure it
  restores the last checkpoint, rebuilds the (restart-safe) data stream
  at the restored step, and continues.  Fault-injection drills go
  through the SHARED seam (:class:`repro.core.exec.resilience.FaultSpec`
  — one spelling, one env var, one deterministic hash schedule): pass
  ``faults="runtime=0.1,seed=3"`` / a :class:`FaultSpec`, or let the
  default resolution read ``REPRO_FAULT_SPEC`` exactly like the sweep
  dispatcher.  The legacy ``fault_hook`` stays as an escape hatch for
  step-pinned drills (see :func:`drill_at`).
* :class:`StragglerMonitor` — per-step wall-time EWMA + rolling median;
  a step slower than ``threshold x`` the running median is flagged.  On
  a real fleet the action is re-scheduling/evicting the slow host; here
  the monitor records events.  The EWMA/median machinery is shared: the
  serving-time contention watchdog (:mod:`repro.serve.monitor`) builds
  its hysteresis band on this class.
* :func:`elastic_remesh` — moves a TrainState onto a *different* mesh
  (fewer/more devices) via the mesh-agnostic checkpoint contract: gather
  to host, re-device_put under the new shardings.  This is the node-loss
  recovery path: drop to a smaller mesh, keep training, grow back later.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.core.exec.resilience import (FaultInjector, FaultSpec,
                                        InjectedFault, resolve_faults)

__all__ = ["InjectedFault", "FaultSpec", "LoopResult", "ResilientLoop",
           "StragglerEvent", "StragglerMonitor", "drill_at",
           "elastic_remesh"]


@dataclass
class StragglerEvent:
    step: int
    wall_s: float
    median_s: float


class StragglerMonitor:
    """Per-step wall-time tracker: rolling median over ``window`` steps
    plus an exponentially-weighted moving average (``ewma_alpha``).
    :meth:`record` flags a step slower than ``threshold x`` the running
    median; :meth:`median` / ``ewma_s`` expose the smoothed state for
    composition (the serve watchdog's deviation test runs on them)."""

    def __init__(self, threshold: float = 3.0, window: int = 32,
                 ewma_alpha: float = 0.2):
        self.threshold = threshold
        self.window = window
        self.ewma_alpha = ewma_alpha
        self.ewma_s: Optional[float] = None
        self.times: List[float] = []
        self.events: List[StragglerEvent] = []

    def median(self, exclude_last: bool = False) -> Optional[float]:
        """Rolling median of the last ``window`` recorded steps."""
        hist = self.times[-self.window:]
        if exclude_last:
            hist = hist[:-1]
        if not hist:
            return None
        return float(np.median(hist))

    def reset(self) -> None:
        """Forget the timing history (events are kept) — called when
        the regime legitimately changed (re-mesh, cache migration)."""
        self.times.clear()
        self.ewma_s = None

    def record(self, step: int, wall_s: float) -> Optional[StragglerEvent]:
        self.times.append(wall_s)
        a = self.ewma_alpha
        self.ewma_s = (wall_s if self.ewma_s is None
                       else a * wall_s + (1.0 - a) * self.ewma_s)
        if len(self.times[-self.window:]) < 5:
            return None
        med = self.median(exclude_last=True)
        if wall_s > self.threshold * med:
            ev = StragglerEvent(step, wall_s, med)
            self.events.append(ev)
            return ev
        return None


def drill_at(at_step: int) -> Callable[[int], None]:
    """A step-pinned one-shot drill hook in the shared fault spelling:
    raises :class:`InjectedFault("runtime_error", ...)` the first time
    the loop reaches ``at_step`` (the ``--inject-fault-at`` CLI path)."""
    fired = {"done": False}

    def hook(step: int) -> None:
        if step == at_step and not fired["done"]:
            fired["done"] = True
            raise InjectedFault("runtime_error", f"train-drill-{step}")

    return hook


@dataclass
class LoopResult:
    final_step: int
    metrics_history: List[Dict[str, float]] = field(default_factory=list)
    restarts: int = 0
    faults_injected: int = 0
    straggler_events: List[StragglerEvent] = field(default_factory=list)


class ResilientLoop:
    """Checkpoint/restart train loop with straggler tracking.

    ``step_fn(state, batch) -> (state, metrics)`` must be pure (jit'd);
    ``batch_fn(step) -> batch`` must be restart-safe (pure function of the
    step index — see data.pipeline.SyntheticSource).

    ``faults`` resolves exactly like the sweep coordinator's
    (:func:`repro.core.exec.resilience.resolve_faults`): ``None`` reads
    ``REPRO_FAULT_SPEC``, ``False``/``"off"`` pins injection off, a
    spec string parses, a :class:`FaultSpec` passes through.  Each step
    is one injection site (``train-step-<n>``), so a restart that
    replays the step sees a FRESH deterministic draw — the same
    attempt-counter discipline the dispatcher uses.
    """

    def __init__(self, step_fn: Callable, batch_fn: Callable,
                 ckpt: CheckpointManager, *, checkpoint_every: int = 100,
                 max_restarts: int = 3,
                 faults=False,
                 fault_hook: Optional[Callable[[int], None]] = None,
                 monitor: Optional[StragglerMonitor] = None,
                 async_checkpoint: bool = True):
        self.step_fn = step_fn
        self.batch_fn = batch_fn
        self.ckpt = ckpt
        self.checkpoint_every = checkpoint_every
        self.max_restarts = max_restarts
        self.fault_spec = resolve_faults(faults)
        self._injector: Optional[FaultInjector] = (
            self.fault_spec.injector() if self.fault_spec else None)
        self.fault_hook = fault_hook
        self.monitor = monitor or StragglerMonitor()
        self.async_checkpoint = async_checkpoint

    def _maybe_inject(self, step: int) -> None:
        if self._injector is not None:
            kind = self._injector.check(f"train-step-{step}", "dispatch")
            if kind is not None:
                raise self._injector.error(kind, f"train-step-{step}")
        if self.fault_hook is not None:
            self.fault_hook(step)

    def run(self, state, n_steps: int, start_step: int = 0) -> LoopResult:
        result = LoopResult(final_step=start_step)
        step = start_step
        restarts = 0
        while step < n_steps:
            try:
                self._maybe_inject(step)
                batch = self.batch_fn(step)
                t0 = time.perf_counter()
                state, metrics = self.step_fn(state, batch)
                jax.tree.map(
                    lambda x: x.block_until_ready()
                    if hasattr(x, "block_until_ready") else x, metrics)
                wall = time.perf_counter() - t0
                ev = self.monitor.record(step, wall)
                if ev is not None:
                    result.straggler_events.append(ev)
                result.metrics_history.append(
                    {k: float(v) for k, v in metrics.items()
                     if np.ndim(v) == 0})
                step += 1
                if step % self.checkpoint_every == 0 or step == n_steps:
                    if self.async_checkpoint:
                        self.ckpt.save_async(state, step)
                    else:
                        self.ckpt.save(state, step)
            except InjectedFault:
                result.faults_injected += 1
                restarts += 1
                if restarts > self.max_restarts:
                    raise
                self.ckpt.wait()
                restore_step = self.ckpt.latest_step()
                if restore_step is None:
                    # no checkpoint yet: restart from the initial state
                    step = start_step
                    continue
                struct = jax.tree.map(
                    lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                    state)
                state = self.ckpt.restore(struct, restore_step)
                step = restore_step
        self.ckpt.wait()
        result.final_step = step
        result.restarts = restarts
        return result


# ---------------------------------------------------------------------------
# Elastic re-mesh
# ---------------------------------------------------------------------------


def elastic_remesh(state, new_shardings):
    """Move a state pytree onto new shardings (possibly a different mesh /
    device count).  Gather-to-host keeps it simple and mesh-agnostic; on a
    real fleet the same contract is fulfilled by resharded checkpoint
    restore so the gather never materialises on one host."""
    host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
    return jax.tree.map(
        lambda arr, sh: jax.device_put(arr, sh), host, new_shardings)
