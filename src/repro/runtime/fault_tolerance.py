"""Fault tolerance: resilient train loop, straggler monitor, elastic re-mesh.

Designed for the 1000-node regime where *something is always failing*:

* :class:`ResilientLoop` — wraps the train step; on a step failure it
  restores the last checkpoint, rebuilds the (restart-safe) data stream
  at the restored step, and continues.  Fault injection hooks let tests
  exercise the real recovery path.
* :class:`StragglerMonitor` — per-step wall-time EWMA + deviation; a step
  slower than ``threshold x`` the running median is flagged.  On a real
  fleet the action is re-scheduling/evicting the slow host; here the
  monitor records events and (optionally) triggers an elastic re-mesh.
* :func:`elastic_remesh` — moves a TrainState onto a *different* mesh
  (fewer/more devices) via the mesh-agnostic checkpoint contract: gather
  to host, re-device_put under the new shardings.  This is the node-loss
  recovery path: drop to a smaller mesh, keep training, grow back later.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager


@dataclass
class StragglerEvent:
    step: int
    wall_s: float
    median_s: float


class StragglerMonitor:
    def __init__(self, threshold: float = 3.0, window: int = 32):
        self.threshold = threshold
        self.window = window
        self.times: List[float] = []
        self.events: List[StragglerEvent] = []

    def record(self, step: int, wall_s: float) -> Optional[StragglerEvent]:
        self.times.append(wall_s)
        hist = self.times[-self.window:]
        if len(hist) < 5:
            return None
        med = float(np.median(hist[:-1]))
        if wall_s > self.threshold * med:
            ev = StragglerEvent(step, wall_s, med)
            self.events.append(ev)
            return ev
        return None


class InjectedFault(RuntimeError):
    """Raised by fault-injection hooks (tests / chaos drills)."""


@dataclass
class LoopResult:
    final_step: int
    metrics_history: List[Dict[str, float]] = field(default_factory=list)
    restarts: int = 0
    straggler_events: List[StragglerEvent] = field(default_factory=list)


class ResilientLoop:
    """Checkpoint/restart train loop with straggler tracking.

    ``step_fn(state, batch) -> (state, metrics)`` must be pure (jit'd);
    ``batch_fn(step) -> batch`` must be restart-safe (pure function of the
    step index — see data.pipeline.SyntheticSource).
    """

    def __init__(self, step_fn: Callable, batch_fn: Callable,
                 ckpt: CheckpointManager, *, checkpoint_every: int = 100,
                 max_restarts: int = 3,
                 fault_hook: Optional[Callable[[int], None]] = None,
                 monitor: Optional[StragglerMonitor] = None,
                 async_checkpoint: bool = True):
        self.step_fn = step_fn
        self.batch_fn = batch_fn
        self.ckpt = ckpt
        self.checkpoint_every = checkpoint_every
        self.max_restarts = max_restarts
        self.fault_hook = fault_hook
        self.monitor = monitor or StragglerMonitor()
        self.async_checkpoint = async_checkpoint

    def run(self, state, n_steps: int, start_step: int = 0) -> LoopResult:
        result = LoopResult(final_step=start_step)
        step = start_step
        restarts = 0
        while step < n_steps:
            try:
                if self.fault_hook is not None:
                    self.fault_hook(step)
                batch = self.batch_fn(step)
                t0 = time.perf_counter()
                state, metrics = self.step_fn(state, batch)
                jax.tree.map(
                    lambda x: x.block_until_ready()
                    if hasattr(x, "block_until_ready") else x, metrics)
                wall = time.perf_counter() - t0
                ev = self.monitor.record(step, wall)
                if ev is not None:
                    result.straggler_events.append(ev)
                result.metrics_history.append(
                    {k: float(v) for k, v in metrics.items()
                     if np.ndim(v) == 0})
                step += 1
                if step % self.checkpoint_every == 0 or step == n_steps:
                    if self.async_checkpoint:
                        self.ckpt.save_async(state, step)
                    else:
                        self.ckpt.save(state, step)
            except InjectedFault:
                restarts += 1
                if restarts > self.max_restarts:
                    raise
                self.ckpt.wait()
                restore_step = self.ckpt.latest_step()
                if restore_step is None:
                    # no checkpoint yet: restart from the initial state
                    step = start_step
                    continue
                struct = jax.tree.map(
                    lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
                    state)
                state = self.ckpt.restore(struct, restore_step)
                step = restore_step
        self.ckpt.wait()
        result.final_step = step
        result.restarts = restarts
        return result


# ---------------------------------------------------------------------------
# Elastic re-mesh
# ---------------------------------------------------------------------------


def elastic_remesh(state, new_shardings):
    """Move a state pytree onto new shardings (possibly a different mesh /
    device count).  Gather-to-host keeps it simple and mesh-agnostic; on a
    real fleet the same contract is fulfilled by resharded checkpoint
    restore so the gather never materialises on one host."""
    host = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), state)
    return jax.tree.map(
        lambda arr, sh: jax.device_put(arr, sh), host, new_shardings)
